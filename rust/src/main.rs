//! `aurora` — CLI for the Aurora MoE inference optimizer.
//!
//! Subcommands:
//! * `eval --figure <11a|11b|11c|11d|12|13|14|a1|a2|ablation|multi|all>` —
//!   regenerate a
//!   paper figure (or the multi-model extension) on synthetic LIMoE traces.
//! * `plan --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>]` —
//!   print a deployment plan as JSON. N ≤ 2 with one expert per GPU uses the
//!   paper's exact paths; anything else uses the generalized placement core.
//! * `simulate --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>]`
//!   — per-layer inference times and utilization for the planned deployment.
//! * `trace --out <file>` — dump the generated traces to JSON.
//! * `serve` — run the end-to-end serving demo on the AOT-compiled MoE model
//!   (requires `make artifacts`).

use aurora::config::EvalConfig;
use aurora::eval::{multi_workload, run_figure, Workloads};
use aurora::planner::Planner;
use aurora::schedule::SchedulePolicy;
use aurora::sim::{simulate_colocated, simulate_exclusive};
use aurora::trace::{trace_to_json, ModelTrace};
use aurora::util::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let opts = Opts::parse(&args[1..]);
    let result = match cmd {
        "eval" => cmd_eval(&opts),
        "plan" => cmd_plan(&opts),
        "simulate" => cmd_simulate(&opts),
        "trace" => cmd_trace(&opts),
        "serve" => cmd_serve(&opts),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "aurora — MoE inference optimization (paper reproduction)

USAGE:
  aurora eval     --figure <11a|11b|11c|11d|12|13|14|a1|a2|ablation|multi|all> [--config f.json] [--json out.json]
  aurora plan     --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>] [--config f.json]
  aurora simulate --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>] [--policy aurora|sjf|ljf|pairwise|rcs]
  aurora trace    --out <file.json> [--config f.json]
  aurora serve    [--artifacts DIR] [--requests N] [--batch N] [--policy aurora|rcs]

  --models N           colocate N models (N >= 3 uses the generalized placement core)
  --experts-per-gpu K  give every model K*n_gpus experts (K >= 2 packs multiple experts per GPU)
"
    );
}

/// Tiny flag parser: `--key value` pairs (the offline build has no `clap`).
struct Opts {
    kv: Vec<(String, String)>,
}

impl Opts {
    fn parse(args: &[String]) -> Opts {
        let mut kv = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                kv.push((key.to_string(), val));
            } else {
                eprintln!("warning: ignoring stray argument '{a}'");
            }
            i += 1;
        }
        Opts { kv }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn config(&self) -> Result<EvalConfig, String> {
        EvalConfig::load(self.get("config"))
    }

    fn policy(&self) -> Result<SchedulePolicy, String> {
        match self.get("policy").unwrap_or("aurora") {
            "aurora" => Ok(SchedulePolicy::Aurora),
            "sjf" => Ok(SchedulePolicy::Sjf),
            "ljf" => Ok(SchedulePolicy::Ljf),
            "pairwise" => Ok(SchedulePolicy::Pairwise),
            "rcs" => Ok(SchedulePolicy::Rcs { seed: 0 }),
            other => Err(format!("unknown policy '{other}'")),
        }
    }
}

fn cmd_eval(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let figure = opts.get("figure").unwrap_or("all");
    let reports = run_figure(figure, &cfg)?;
    for r in &reports {
        println!("{}", r.render());
    }
    if let Some(path) = opts.get("json") {
        let arr = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.to_string_compact()).map_err(|e| format!("{path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cluster_for(opts: &Opts, cfg: &EvalConfig) -> Result<aurora::Cluster, String> {
    match opts.get("cluster").unwrap_or("homo") {
        "homo" | "homogeneous" => Ok(cfg.homogeneous_cluster()),
        "hetero" | "heterogeneous" => Ok(cfg.heterogeneous_cluster()),
        other => Err(format!("unknown cluster '{other}'")),
    }
}

/// Parse and validate `--models` / `--experts-per-gpu`. `experts_per_gpu`
/// is `None` when the flag is absent — `None` with N ≤ 2 is the paper's
/// shape (classic `DeploymentPlan` output); anything else takes the
/// generalized placement path.
fn parse_shape(opts: &Opts) -> Result<(usize, Option<usize>), String> {
    let models: usize = opts
        .get("models")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --models")?;
    if models == 0 {
        return Err("--models must be >= 1".into());
    }
    let per_gpu = match opts.get("experts-per-gpu") {
        None => None,
        Some(s) => {
            let k: usize = s.parse().map_err(|_| "bad --experts-per-gpu")?;
            if k == 0 {
                return Err("--experts-per-gpu must be >= 1".into());
            }
            // An explicit K=1 is the default shape: normalize so it plans
            // the same workload as omitting the flag.
            if k == 1 {
                None
            } else {
                Some(k)
            }
        }
    };
    Ok((models, per_gpu))
}

fn cmd_plan(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let cluster = cluster_for(opts, &cfg)?;
    let planner = Planner::default();
    let (models, per_gpu) = parse_shape(opts)?;
    // The paper's shapes print the classic two-model plan JSON for parity.
    if per_gpu.is_none() && models <= 2 {
        let w = Workloads::generate(&cfg);
        let plan = match models {
            1 => planner.plan_exclusive(&w.b16_coco, &cluster),
            _ => planner.plan_colocated(&w.b16_coco, &w.b32_coco, &cluster),
        };
        println!("{}", plan.to_json().to_string_compact());
        return Ok(());
    }
    let n_experts = per_gpu.unwrap_or(1) * cluster.len();
    let traces = multi_workload(&cfg, models, n_experts);
    let refs: Vec<&ModelTrace> = traces.iter().collect();
    let dep = planner
        .plan_multi(&refs, &cluster)
        .map_err(|e| e.to_string())?;
    println!("{}", dep.to_json().to_string_compact());
    Ok(())
}

fn cmd_simulate(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let cluster = cluster_for(opts, &cfg)?;
    let policy = opts.policy()?;
    let planner = Planner {
        policy,
        planning_layer: 0,
    };
    let (models, per_gpu) = parse_shape(opts)?;
    println!(
        "scenario: {} model(s), {} cluster, policy {}",
        models,
        if cluster.is_homogeneous() {
            "homogeneous"
        } else {
            "heterogeneous"
        },
        policy.name()
    );
    match (models, per_gpu) {
        (1, None) => {
            let w = Workloads::generate(&cfg);
            let plan = planner.plan_exclusive(&w.b16_coco, &cluster);
            for (k, layer) in plan.place_a(&w.b16_coco).iter().enumerate() {
                let (res, _) = simulate_exclusive(layer, &cluster, policy);
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
        }
        (2, None) => {
            let w = Workloads::generate(&cfg);
            let plan = planner.plan_colocated(&w.b16_coco, &w.b32_coco, &cluster);
            let pa = plan.place_a(&w.b16_coco);
            let pb = plan.place_b(&w.b32_coco);
            for (k, (la, lb)) in pa.iter().zip(&pb).enumerate() {
                let (res, _) = simulate_colocated(la, lb, &cluster, policy);
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, agg comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
        }
        _ => {
            // Generalized path: N models, K experts per GPU slot.
            let k = per_gpu.unwrap_or(1);
            let traces = multi_workload(&cfg, models, k * cluster.len());
            let refs: Vec<&ModelTrace> = traces.iter().collect();
            let dep = planner
                .plan_multi(&refs, &cluster)
                .map_err(|e| e.to_string())?;
            println!(
                "deployment: {} models x {} experts ({} per GPU slot), max group {}",
                dep.n_models(),
                dep.n_experts(0),
                k,
                dep.max_group_size()
            );
            for (k, res) in dep.simulate(&refs, &cluster).iter().enumerate() {
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, agg comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
        }
    }
    Ok(())
}

fn cmd_trace(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let w = Workloads::generate(&cfg);
    let out = opts.get("out").ok_or("--out required")?;
    let arr = Json::Arr(
        [&w.b16_coco, &w.b16_imagenet, &w.b32_coco, &w.b32_imagenet]
            .iter()
            .map(|t| trace_to_json(t))
            .collect(),
    );
    std::fs::write(out, arr.to_string_compact()).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<(), String> {
    let artifacts = opts.get("artifacts").unwrap_or("artifacts");
    let requests: usize = opts
        .get("requests")
        .unwrap_or("64")
        .parse()
        .map_err(|_| "bad --requests")?;
    let batch: usize = opts
        .get("batch")
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --batch")?;
    let policy = opts.policy()?;
    aurora::serve::demo::run_serving_demo(artifacts, requests, batch, policy)
        .map_err(|e| e.to_string())
}
