//! Incremental evaluation of replica-addition candidates.
//!
//! [`crate::planner::Planner::plan_replicated`]'s greedy loop prices every
//! candidate `(model, expert, gpu)` replica by its post-addition bottleneck.
//! Doing that from scratch costs three O(models · experts²) passes per
//! candidate (re-deriving expert loads inside [`super::optimize_splits`],
//! the split projection of [`super::estimate_per_gpu_replicated`], and the
//! [`super::ReplicatedDeployment::aggregated_traffic_split`] pass feeding
//! the uplink bound). [`ReplicaDeltaEstimator`] collapses a candidate
//! evaluation to:
//!
//! 1. re-solving the water-filling split plan with the candidate's replica
//!    set substituted (O(experts + replicated·k log k), expert loads cached
//!    — the `solve_splits` core shared with [`super::optimize_splits`], so
//!    the weights are bit-for-bit identical);
//! 2. diffing the candidate plan against the committed one and re-applying
//!    only the **changed experts'** traffic contributions to cloned integer
//!    counters (each O(expert degree · replica count); water-filling makes
//!    an expert's weights change only when the candidate perturbed the
//!    levels its fill saw, so most experts are bitwise unchanged and skip);
//! 3. reading the objective off the counters in O(GPUs · models + groups).
//!
//! All maintained state is integer token counters, so committed updates are
//! exact and the derived estimates equal the from-scratch
//! [`super::estimate_per_gpu_replicated`] / [`crate::cluster::uplink_bound`]
//! values bit for bit (pinned by the `prop_replica_delta_matches_full`
//! property test after randomized replica additions).

use super::split::solve_splits;
use super::{ReplicatedDeployment, SplitPlan};
use crate::cluster::{Cluster, Topology};
use crate::sim::MoeLayerStats;
use crate::traffic::split_tokens;

/// The integer token counters an evaluation reads its objective from.
#[derive(Debug, Clone)]
struct Counters {
    /// `gpu_load[m][g]` = model `m`'s (split-integerized) token load on `g`.
    gpu_load: Vec<Vec<u64>>,
    /// Cross-GPU tokens sent from each GPU (aggregate, diagonal excluded).
    out: Vec<u64>,
    /// Cross-GPU tokens received at each GPU.
    inn: Vec<u64>,
    /// Cross-group tokens leaving each group, one counter set per
    /// aggregation level (empty on the big switch).
    up: Vec<Vec<u64>>,
    /// Cross-group tokens entering each group, per level.
    down: Vec<Vec<u64>>,
}

/// Per-expert traffic placement context shared by the contribution walks.
struct Contrib<'c> {
    m: usize,
    layer: &'c MoeLayerStats,
    /// Primaries of model `m` (token sources are keyed by the sender
    /// expert's primary GPU, exactly as in
    /// [`crate::traffic::TrafficMatrix::project_split`]).
    assignment: &'c [usize],
    /// GPU → group maps, one per aggregation level.
    owners: &'c [Vec<usize>],
}

impl Counters {
    /// Add (or subtract) destination expert `j`'s entire inbound traffic —
    /// every sender's tokens split across `set` by `weights` — exactly as
    /// `project_split` places it.
    fn contribute(
        &mut self,
        add: bool,
        ctx: &Contrib<'_>,
        j: usize,
        set: &[usize],
        weights: &[f64],
    ) {
        for (i, t) in ctx.layer.traffic.col_iter(j) {
            let src = ctx.assignment[i];
            if set.len() == 1 {
                self.place(add, ctx, src, set[0], t);
            } else {
                for (r, part) in split_tokens(t, weights).into_iter().enumerate() {
                    if part > 0 {
                        self.place(add, ctx, src, set[r], part);
                    }
                }
            }
        }
    }

    fn place(&mut self, add: bool, ctx: &Contrib<'_>, src: usize, dst: usize, t: u64) {
        if add {
            self.gpu_load[ctx.m][dst] += t;
        } else {
            self.gpu_load[ctx.m][dst] -= t;
        }
        if src == dst {
            return;
        }
        if add {
            self.out[src] += t;
            self.inn[dst] += t;
        } else {
            self.out[src] -= t;
            self.inn[dst] -= t;
        }
        for (l, ow) in ctx.owners.iter().enumerate() {
            let (hs, hd) = (ow[src], ow[dst]);
            if hs != hd {
                if add {
                    self.up[l][hs] += t;
                    self.down[l][hd] += t;
                } else {
                    self.up[l][hs] -= t;
                    self.down[l][hd] -= t;
                }
            }
        }
    }
}

/// Incremental evaluator for the replication greedy: committed split plan,
/// per-GPU completion estimates, and per-uplink counters, with O(changed
/// experts) candidate pricing ([`ReplicaDeltaEstimator::eval_add`]) and
/// exact commits ([`ReplicaDeltaEstimator::commit_add`]).
///
/// Primaries are fixed for the evaluator's lifetime (the greedy only adds
/// copies; the primary-moving refinement runs afterwards on its own
/// machinery).
#[derive(Debug, Clone)]
pub struct ReplicaDeltaEstimator<'a> {
    layers: &'a [&'a MoeLayerStats],
    cluster: &'a Cluster,
    /// GPU → group maps, one per aggregation level (empty on the big
    /// switch).
    owners: Vec<Vec<usize>>,
    /// Per-group uplink rates, per level.
    rates: Vec<Vec<f64>>,
    /// Primaries per model (fixed).
    assignments: Vec<Vec<usize>>,
    /// Committed replica sets.
    sets: Vec<Vec<Vec<usize>>>,
    /// Cached per-expert token loads per model.
    loads: Vec<Vec<u64>>,
    /// Committed split plan — always `optimize_splits` of the committed
    /// sets, bit for bit.
    plan: SplitPlan,
    counters: Counters,
    /// Committed per-GPU completion estimates.
    costs: Vec<f64>,
}

impl<'a> ReplicaDeltaEstimator<'a> {
    /// Build the committed state from scratch — one O(models · experts²)
    /// pass, the same cost as a single from-scratch evaluation.
    ///
    /// Panics when `topo` does not fit the cluster (the planner validates
    /// topologies before replication runs).
    pub fn new(
        rep: &ReplicatedDeployment,
        layers: &'a [&'a MoeLayerStats],
        cluster: &'a Cluster,
        topo: &Topology,
    ) -> ReplicaDeltaEstimator<'a> {
        assert_eq!(layers.len(), rep.n_models(), "one layer per model");
        assert_eq!(cluster.len(), rep.n_gpus(), "cluster must match the deployment");
        let n = rep.n_gpus();
        let n_levels = topo.n_levels();
        let owners: Vec<Vec<usize>> = (0..n_levels)
            .map(|l| topo.owners_at(n, l).expect("invalid topology"))
            .collect();
        let rates: Vec<Vec<f64>> = (0..n_levels)
            .map(|l| topo.uplink_rates_at(cluster, l))
            .collect();
        let loads: Vec<Vec<u64>> = layers.iter().map(|l| l.expert_loads()).collect();
        let sets = rep.replicas.clone();
        let plan = solve_splits(&sets, None, &loads, layers, cluster);
        let mut counters = Counters {
            gpu_load: vec![vec![0u64; n]; layers.len()],
            out: vec![0u64; n],
            inn: vec![0u64; n],
            up: rates.iter().map(|r| vec![0u64; r.len()]).collect(),
            down: rates.iter().map(|r| vec![0u64; r.len()]).collect(),
        };
        for (m, layer) in layers.iter().enumerate() {
            let ctx = Contrib {
                m,
                layer: *layer,
                assignment: &rep.base.assignments[m],
                owners: &owners,
            };
            for j in 0..sets[m].len() {
                counters.contribute(true, &ctx, j, &sets[m][j], &plan.weights[m][j]);
            }
        }
        let mut est = ReplicaDeltaEstimator {
            layers,
            cluster,
            owners,
            rates,
            assignments: rep.base.assignments.clone(),
            sets,
            loads,
            plan,
            counters,
            costs: vec![0.0; n],
        };
        est.costs = (0..n).map(|g| est.cost_of(&est.counters, g)).collect();
        est
    }

    /// Completion estimate of GPU `g` from a counter set, in
    /// [`super::estimate_per_gpu_replicated`]'s exact operation order.
    fn cost_of(&self, c: &Counters, g: usize) -> f64 {
        let mut compute = 0.0f64;
        for (m, layer) in self.layers.iter().enumerate() {
            compute +=
                layer.gate_ms + layer.agg_ms + c.gpu_load[m][g] as f64 * layer.ffn_ms_per_token;
        }
        let gpu = self.cluster.gpu(g);
        let wire = c.out[g].max(c.inn[g]) as f64 / gpu.bandwidth;
        compute / gpu.flops_scale + wire
    }

    /// Bottleneck objective from a counter set: max per-GPU completion
    /// estimate, joined with the uplink drain on two-tier fabrics.
    fn objective_of(&self, c: &Counters) -> f64 {
        let mut mx = 0.0f64;
        for g in 0..self.cluster.len() {
            mx = mx.max(self.cost_of(c, g));
        }
        let mut bound = 0.0f64;
        for l in 0..self.owners.len() {
            for ((&u, &d), &r) in c.up[l].iter().zip(&c.down[l]).zip(&self.rates[l]) {
                bound = bound.max(u as f64 / r).max(d as f64 / r);
            }
        }
        mx.max(bound)
    }

    /// Re-place the contributions of every expert whose split weights (or
    /// replica set) differ between the committed plan and `cand` onto `c`.
    fn apply_plan_diff(
        &self,
        c: &mut Counters,
        m: usize,
        e: usize,
        new_set: &[usize],
        cand: &SplitPlan,
    ) {
        for (mm, model) in cand.weights.iter().enumerate() {
            let ctx = Contrib {
                m: mm,
                layer: self.layers[mm],
                assignment: &self.assignments[mm],
                owners: &self.owners,
            };
            for (j, w) in model.iter().enumerate() {
                let is_cand = mm == m && j == e;
                if !is_cand && *w == self.plan.weights[mm][j] {
                    continue;
                }
                c.contribute(false, &ctx, j, &self.sets[mm][j], &self.plan.weights[mm][j]);
                let set: &[usize] = if is_cand { new_set } else { &self.sets[mm][j] };
                c.contribute(true, &ctx, j, set, w);
            }
        }
    }

    /// Price the candidate "add a replica of model `m`'s expert `e` on GPU
    /// `g`": the bottleneck objective the deployment would have after the
    /// addition, identical to a from-scratch re-evaluation. Read-only (safe
    /// to call from parallel sweep workers).
    pub fn eval_add(&self, m: usize, e: usize, g: usize) -> f64 {
        let mut new_set = self.sets[m][e].clone();
        new_set.push(g);
        let cand = solve_splits(
            &self.sets,
            Some((m, e, new_set.as_slice())),
            &self.loads,
            self.layers,
            self.cluster,
        );
        let mut scratch = self.counters.clone();
        self.apply_plan_diff(&mut scratch, m, e, &new_set, &cand);
        self.objective_of(&scratch)
    }

    /// Commit the replica addition: counters, split plan, replica sets, and
    /// per-GPU costs all advance to the post-addition state.
    pub fn commit_add(&mut self, m: usize, e: usize, g: usize) {
        let mut new_set = self.sets[m][e].clone();
        new_set.push(g);
        let cand = solve_splits(
            &self.sets,
            Some((m, e, new_set.as_slice())),
            &self.loads,
            self.layers,
            self.cluster,
        );
        let mut c = self.counters.clone();
        self.apply_plan_diff(&mut c, m, e, &new_set, &cand);
        self.counters = c;
        self.plan = cand;
        self.sets[m][e] = new_set;
        let n = self.cluster.len();
        self.costs = (0..n).map(|g| self.cost_of(&self.counters, g)).collect();
    }

    /// Committed per-GPU completion estimates — equal to
    /// [`super::estimate_per_gpu_replicated`] under the committed plan.
    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Committed bottleneck objective (max completion estimate ∨ uplink
    /// drain) — read off the cached committed costs, no recomputation.
    pub fn objective(&self) -> f64 {
        let mx = self.costs.iter().cloned().fold(0.0, f64::max);
        mx.max(self.uplink_drain_ms())
    }

    /// Committed uplink drain (ms), the max across every aggregation level;
    /// `0.0` on the big switch.
    pub fn uplink_drain_ms(&self) -> f64 {
        let mut bound = 0.0f64;
        for l in 0..self.owners.len() {
            for ((&u, &d), &r) in self.counters.up[l]
                .iter()
                .zip(&self.counters.down[l])
                .zip(&self.rates[l])
            {
                bound = bound.max(u.max(d) as f64 / r);
            }
        }
        bound
    }

    /// The committed split plan — bit-for-bit [`super::optimize_splits`] of
    /// the committed replica sets.
    pub fn plan(&self) -> &SplitPlan {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::uplink_bound;
    use crate::placement::{Deployment, Scenario};
    use crate::replication::{estimate_per_gpu_replicated, optimize_splits};
    use crate::schedule::SchedulePolicy;
    use crate::traffic::zipf_traffic;

    fn hot_layer(n: usize, alpha: f64, seed: u64) -> MoeLayerStats {
        MoeLayerStats {
            traffic: zipf_traffic(n, 512, alpha, seed),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        }
    }

    fn rep(n_experts: usize, n_gpus: usize) -> ReplicatedDeployment {
        let base = Deployment::new(
            n_gpus,
            vec![(0..n_experts).map(|e| e % n_gpus).collect()],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        ReplicatedDeployment::from_deployment(base)
    }

    #[test]
    fn committed_state_matches_full_rescan_after_adds() {
        let l = hot_layer(16, 1.2, 7);
        let layers = [&l];
        let cluster = Cluster::homogeneous(8, 100.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let mut r = rep(16, 8);
        let mut est = ReplicaDeltaEstimator::new(&r, &layers, &cluster, &topo);
        for (e, g) in [(0usize, 1usize), (0, 5), (8, 3), (1, 7), (0, 2)] {
            // exactness of the candidate price: push, full rescan, compare
            let predicted = est.eval_add(0, e, g);
            r.replicas[0][e].push(g);
            let full_plan = optimize_splits(&r, &layers, &cluster);
            let full_costs = estimate_per_gpu_replicated(&r, &layers, &cluster, &full_plan);
            let agg = r.aggregated_traffic_split(&layers, &full_plan);
            let full_obj = full_costs
                .iter()
                .cloned()
                .fold(0.0, f64::max)
                .max(uplink_bound(&agg, &cluster, &topo));
            assert!(
                (predicted - full_obj).abs() < 1e-12,
                "expert {e} -> gpu {g}: predicted {predicted} vs full {full_obj}"
            );
            // commit and compare the whole committed state
            est.commit_add(0, e, g);
            assert_eq!(est.plan(), &full_plan, "expert {e} -> gpu {g}");
            for (gpu, &c) in full_costs.iter().enumerate() {
                assert!(
                    (est.costs()[gpu] - c).abs() < 1e-12,
                    "gpu {gpu}: {} vs {c}",
                    est.costs()[gpu]
                );
            }
            assert!((est.objective() - full_obj).abs() < 1e-12);
        }
    }

    #[test]
    fn tiered_committed_state_matches_full_rescan() {
        // candidate prices and committed state must equal the from-scratch
        // objective on a recursive tiered fabric (all levels' drains join)
        let l = hot_layer(16, 1.2, 13);
        let layers = [&l];
        let cluster = Cluster::homogeneous(8, 100.0);
        let topo = Topology::even_tiered(8, &[4, 2], &[2.0, 4.0]).unwrap();
        let mut r = rep(16, 8);
        let mut est = ReplicaDeltaEstimator::new(&r, &layers, &cluster, &topo);
        for (e, g) in [(0usize, 1usize), (0, 6), (4, 2)] {
            let predicted = est.eval_add(0, e, g);
            r.replicas[0][e].push(g);
            let full_plan = optimize_splits(&r, &layers, &cluster);
            let full_costs = estimate_per_gpu_replicated(&r, &layers, &cluster, &full_plan);
            let agg = r.aggregated_traffic_split(&layers, &full_plan);
            let full_obj = full_costs
                .iter()
                .cloned()
                .fold(0.0, f64::max)
                .max(uplink_bound(&agg, &cluster, &topo));
            assert!(
                (predicted - full_obj).abs() < 1e-12,
                "expert {e} -> gpu {g}: predicted {predicted} vs full {full_obj}"
            );
            est.commit_add(0, e, g);
            assert!((est.objective() - full_obj).abs() < 1e-12);
        }
    }

    #[test]
    fn big_switch_objective_is_port_only() {
        let l = hot_layer(8, 1.0, 3);
        let layers = [&l];
        let cluster = Cluster::homogeneous(4, 100.0);
        let r = rep(8, 4);
        let est = ReplicaDeltaEstimator::new(&r, &layers, &cluster, &Topology::BigSwitch);
        assert_eq!(est.uplink_drain_ms(), 0.0);
        let plan = optimize_splits(&r, &layers, &cluster);
        let full = estimate_per_gpu_replicated(&r, &layers, &cluster, &plan);
        let mx = full.iter().cloned().fold(0.0, f64::max);
        assert!((est.objective() - mx).abs() < 1e-12);
    }
}
