//! Expert replication: replica-aware deployments and skew-resilient serving.
//!
//! The placement core ([`crate::placement::Deployment`]) assumes every
//! expert lives on exactly one GPU. Under skewed routing (one expert
//! absorbing a large share of the batch, the regime
//! [`crate::traffic::zipf_traffic`] generates) that single GPU becomes a
//! bottleneck **no transmission ordering can fix**: the hot expert's FFN
//! load and receive-port volume are pinned to one machine. Replication is
//! the next lever — host copies of hot experts on several GPUs and split
//! each sender's tokens across the copies.
//!
//! The subsystem has three parts:
//!
//! * [`ReplicatedDeployment`] — a validated `(model, expert) → {replica
//!   GPUs}` map layered over a base [`Deployment`] (replica 0 is always the
//!   primary). With all-singleton replica sets it degrades to the base
//!   deployment **bit-for-bit**: projection, simulation, and serving all
//!   take the exact placement paths.
//! * [`optimize_splits`] — the fractional token-split optimizer:
//!   water-filling each replicated expert's load across its replica GPUs'
//!   completion levels, yielding a [`SplitPlan`] that
//!   [`crate::traffic::TrafficMatrix::project_split`] turns into GPU-level
//!   traffic (integerized per flow, so schedules built from split matrices
//!   stay conservation-exact and machine-checkable).
//! * [`refine_replicated`] — the swap/move local search of the planner
//!   re-run with the split-aware per-GPU completion estimate
//!   ([`estimate_per_gpu_replicated`]), so primaries can migrate after
//!   replicas change the load landscape.
//!
//! [`crate::planner::Planner::plan_replicated`] drives the whole pipeline:
//! plan a base deployment, greedily replicate the bottleneck GPU's experts
//! while the marginal bottleneck reduction clears a threshold, then refine.
//! The greedy prices its candidates through [`ReplicaDeltaEstimator`]:
//! integer token counters maintained incrementally under
//! replica additions, with candidate split plans re-solved only for the
//! experts whose water-filling actually changed — the engine that scales
//! replication planning to hundreds of GPUs (see "Performance & incremental
//! planning" in `docs/architecture.md`).

mod delta;
mod split;

pub use delta::ReplicaDeltaEstimator;
pub use split::{optimize_splits, SplitPlan};

use crate::cluster::{uplink_bound, Cluster, Topology};
use crate::placement::Deployment;
use crate::sim::{simulate_group, simulate_group_topology, MoeLayerStats, SimResult};
use crate::trace::{aggregate_totals, ModelTrace};
use crate::traffic::{split_tokens, TrafficMatrix};
use crate::util::Json;
use std::fmt;

/// Why a replicated deployment is invalid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplicationError {
    /// The replica map's shape does not match the base deployment.
    ShapeMismatch {
        /// Offending model index (or the model count itself when
        /// `expert == usize::MAX`).
        model: usize,
        /// Offending expert index.
        expert: usize,
    },
    /// An expert has an empty replica set.
    EmptyReplicaSet {
        /// Model index.
        model: usize,
        /// Expert index.
        expert: usize,
    },
    /// Replica 0 must be the base deployment's primary GPU.
    PrimaryMismatch {
        /// Model index.
        model: usize,
        /// Expert index.
        expert: usize,
    },
    /// The same GPU appears twice in one expert's replica set.
    DuplicateReplica {
        /// Model index.
        model: usize,
        /// Expert index.
        expert: usize,
        /// The duplicated GPU id.
        gpu: usize,
    },
    /// A replica was placed on a GPU the cluster does not have.
    GpuOutOfRange {
        /// Model index.
        model: usize,
        /// Expert index.
        expert: usize,
        /// The out-of-range GPU id.
        gpu: usize,
        /// Cluster size.
        n_gpus: usize,
    },
}

impl fmt::Display for ReplicationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplicationError::ShapeMismatch { model, expert } => write!(
                f,
                "replica map shape mismatch at model {model}, expert {expert}"
            ),
            ReplicationError::EmptyReplicaSet { model, expert } => {
                write!(f, "model {model} expert {expert} has no replicas")
            }
            ReplicationError::PrimaryMismatch { model, expert } => write!(
                f,
                "model {model} expert {expert}: replica 0 must be the base deployment's GPU"
            ),
            ReplicationError::DuplicateReplica { model, expert, gpu } => write!(
                f,
                "model {model} expert {expert} lists GPU {gpu} twice"
            ),
            ReplicationError::GpuOutOfRange {
                model,
                expert,
                gpu,
                n_gpus,
            } => write!(
                f,
                "model {model} expert {expert} replica on GPU {gpu}, but the cluster has {n_gpus}"
            ),
        }
    }
}

impl std::error::Error for ReplicationError {}

/// A placement with per-expert replica sets: model `m`'s expert `e` has
/// copies on `replicas[m][e]` (never empty; `replicas[m][e][0]` is the
/// primary, i.e. `base.assignments[m][e]`).
///
/// The base [`Deployment`] keeps the primary-only view — every consumer that
/// is not replica-aware (execution ordering, scenario bookkeeping) reads it
/// unchanged, and a `ReplicatedDeployment` whose sets are all singletons
/// behaves identically to its base.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicatedDeployment {
    /// Primary placement (replica 0 of every expert).
    pub base: Deployment,
    /// `replicas[m][e]` = GPUs hosting copies of model `m`'s expert `e`.
    pub replicas: Vec<Vec<Vec<usize>>>,
}

impl ReplicatedDeployment {
    /// Build and validate a replicated deployment.
    pub fn new(
        base: Deployment,
        replicas: Vec<Vec<Vec<usize>>>,
    ) -> Result<ReplicatedDeployment, ReplicationError> {
        if replicas.len() != base.n_models() {
            return Err(ReplicationError::ShapeMismatch {
                model: replicas.len(),
                expert: usize::MAX,
            });
        }
        for (m, model) in replicas.iter().enumerate() {
            if model.len() != base.n_experts(m) {
                return Err(ReplicationError::ShapeMismatch {
                    model: m,
                    expert: model.len(),
                });
            }
            for (e, set) in model.iter().enumerate() {
                if set.is_empty() {
                    return Err(ReplicationError::EmptyReplicaSet { model: m, expert: e });
                }
                if set[0] != base.gpu_of(m, e) {
                    return Err(ReplicationError::PrimaryMismatch { model: m, expert: e });
                }
                let mut seen = vec![false; base.n_gpus];
                for &g in set {
                    if g >= base.n_gpus {
                        return Err(ReplicationError::GpuOutOfRange {
                            model: m,
                            expert: e,
                            gpu: g,
                            n_gpus: base.n_gpus,
                        });
                    }
                    if seen[g] {
                        return Err(ReplicationError::DuplicateReplica {
                            model: m,
                            expert: e,
                            gpu: g,
                        });
                    }
                    seen[g] = true;
                }
            }
        }
        Ok(ReplicatedDeployment { base, replicas })
    }

    /// The trivial (un-replicated) wrapper: every expert's set is just its
    /// primary GPU. Always valid.
    pub fn from_deployment(base: Deployment) -> ReplicatedDeployment {
        let replicas = base
            .assignments
            .iter()
            .map(|a| a.iter().map(|&g| vec![g]).collect())
            .collect();
        ReplicatedDeployment { base, replicas }
    }

    /// Number of colocated models.
    pub fn n_models(&self) -> usize {
        self.base.n_models()
    }

    /// Cluster size.
    pub fn n_gpus(&self) -> usize {
        self.base.n_gpus
    }

    /// True when at least one expert has more than one replica.
    pub fn is_replicated(&self) -> bool {
        self.replicas
            .iter()
            .any(|model| model.iter().any(|set| set.len() > 1))
    }

    /// Replica count of model `m`'s expert `e`.
    pub fn replica_count(&self, m: usize, e: usize) -> usize {
        self.replicas[m][e].len()
    }

    /// Total number of *extra* copies beyond the primaries.
    pub fn added_replicas(&self) -> usize {
        self.replicas
            .iter()
            .flat_map(|model| model.iter().map(|set| set.len() - 1))
            .sum()
    }

    /// Per-GPU slot occupancy: how many `(model, expert)` copies (primaries
    /// and replicas) each GPU hosts — the quantity a memory budget bounds.
    pub fn slots_per_gpu(&self) -> Vec<usize> {
        let mut slots = vec![0usize; self.n_gpus()];
        for model in &self.replicas {
            for set in model {
                for &g in set {
                    slots[g] += 1;
                }
            }
        }
        slots
    }

    /// Add a replica of model `m`'s expert `e` on `gpu`. Fails on duplicate
    /// or out-of-range GPUs.
    pub fn add_replica(&mut self, m: usize, e: usize, gpu: usize) -> Result<(), ReplicationError> {
        if gpu >= self.n_gpus() {
            return Err(ReplicationError::GpuOutOfRange {
                model: m,
                expert: e,
                gpu,
                n_gpus: self.n_gpus(),
            });
        }
        if self.replicas[m][e].contains(&gpu) {
            return Err(ReplicationError::DuplicateReplica { model: m, expert: e, gpu });
        }
        self.replicas[m][e].push(gpu);
        Ok(())
    }

    /// Degraded-mode promotion: the deployment with every copy hosted on
    /// `gpu` removed. Where a survivor replica exists it is promoted (the
    /// first survivor becomes the primary); an expert whose *only* copy
    /// lived on `gpu` is cold-restored onto the least-occupied placeable
    /// GPU (fewest slots, lowest id as tiebreak — its weights must be
    /// re-fetched from the checkpoint, which the repair replan prices).
    /// Returns the evacuated deployment plus the `(model, expert)` lists of
    /// promoted survivors and cold restores. This is the zero-downtime half
    /// of the coordinator's promote-then-repair contract
    /// ([`crate::coordinator::Coordinator::inject_event`]): no planner call,
    /// just mask-and-renormalize — split weights are re-solved by the caller
    /// via [`optimize_splits`].
    ///
    /// Panics when `placeable` still allows `gpu` (the failed/drained GPU
    /// must be masked first) or when no placeable GPU remains.
    pub fn evacuate_gpu(
        &self,
        gpu: usize,
        placeable: &[bool],
    ) -> (ReplicatedDeployment, Vec<(usize, usize)>, Vec<(usize, usize)>) {
        assert!(gpu < self.n_gpus(), "evacuating GPU {gpu} of {}", self.n_gpus());
        assert_eq!(placeable.len(), self.n_gpus());
        assert!(!placeable[gpu], "the evacuated GPU must be masked un-placeable");
        assert!(
            placeable.iter().any(|&p| p),
            "no placeable GPU left to evacuate onto"
        );
        let mut base = self.base.clone();
        let mut replicas = self.replicas.clone();
        let mut promoted = Vec::new();
        let mut restored = Vec::new();
        // Slot occupancy for restore-target choice, with the evacuated GPU's
        // copies already discounted.
        let mut slots = vec![0usize; self.n_gpus()];
        for model in &replicas {
            for set in model {
                for &g in set {
                    if g != gpu {
                        slots[g] += 1;
                    }
                }
            }
        }
        for (m, model) in replicas.iter_mut().enumerate() {
            for (e, set) in model.iter_mut().enumerate() {
                if !set.contains(&gpu) {
                    continue;
                }
                set.retain(|&g| g != gpu);
                if set.is_empty() {
                    let target = (0..placeable.len())
                        .filter(|&g| placeable[g])
                        .min_by_key(|&g| (slots[g], g))
                        .expect("checked above: at least one placeable GPU");
                    set.push(target);
                    slots[target] += 1;
                    restored.push((m, e));
                } else if base.assignments[m][e] == gpu {
                    promoted.push((m, e));
                }
                // keep the invariant: the primary is the first replica
                base.assignments[m][e] = set[0];
            }
        }
        let rep = ReplicatedDeployment::new(base, replicas)
            .expect("evacuation preserves deployment validity");
        (rep, promoted, restored)
    }

    /// Model `m`'s layer statistics projected onto GPU indices with the
    /// plan's split weights applied: each sender's tokens for a replicated
    /// expert spread across its replica GPUs
    /// ([`TrafficMatrix::project_split`]). With all-singleton sets this is
    /// exactly [`Deployment::project_layer`].
    pub fn project_layer_split(
        &self,
        m: usize,
        layer: &MoeLayerStats,
        plan: &SplitPlan,
    ) -> MoeLayerStats {
        assert_eq!(
            layer.n_experts(),
            self.base.assignments[m].len(),
            "layer expert count must match model {m}'s assignment"
        );
        MoeLayerStats {
            traffic: layer.traffic.project_split(
                &self.base.assignments[m],
                &self.replicas[m],
                &plan.weights[m],
                self.base.n_gpus,
            ),
            ..*layer
        }
    }

    /// Aggregated split GPU-level traffic of all models for one layer set.
    pub fn aggregated_traffic_split(
        &self,
        layers: &[&MoeLayerStats],
        plan: &SplitPlan,
    ) -> TrafficMatrix {
        assert_eq!(layers.len(), self.n_models());
        let mut agg = TrafficMatrix::zeros(self.n_gpus());
        for (m, layer) in layers.iter().enumerate() {
            agg = agg.sum(&self.project_layer_split(m, layer, plan).traffic);
        }
        agg
    }

    /// Aggregate a per-expert token histogram of model `m` into per-GPU
    /// loads under this placement *and* split plan: each expert's count
    /// splits across its replicas by the plan weights (largest-remainder
    /// integerization, [`split_tokens`]). This is what the adaptive
    /// replanner watches for replicated deployments.
    pub fn gpu_loads_split(
        &self,
        m: usize,
        expert_histogram: &[u64],
        plan: &SplitPlan,
    ) -> Vec<u64> {
        assert_eq!(
            expert_histogram.len(),
            self.base.assignments[m].len(),
            "histogram must cover model {m}'s experts"
        );
        let mut loads = vec![0u64; self.n_gpus()];
        for (e, &count) in expert_histogram.iter().enumerate() {
            let set = &self.replicas[m][e];
            if set.len() == 1 {
                loads[set[0]] += count;
                continue;
            }
            for (r, part) in split_tokens(count, &plan.weights[m][e]).into_iter().enumerate() {
                loads[set[r]] += part;
            }
        }
        loads
    }

    /// Optimize a [`SplitPlan`] for full traces: split weights are chosen on
    /// each model's aggregate (all-layer) traffic, the same statistics the
    /// planner's general path plans on.
    pub fn plan_splits(&self, traces: &[&ModelTrace], cluster: &Cluster) -> SplitPlan {
        let totals = aggregate_totals(traces);
        let refs: Vec<&MoeLayerStats> = totals.iter().collect();
        optimize_splits(self, &refs, cluster)
    }

    /// Simulate one layer set under this replicated placement and `plan`:
    /// project every model with split weights and run the generalized group
    /// simulator under the base deployment's policy.
    pub fn simulate_layer(
        &self,
        layers: &[&MoeLayerStats],
        cluster: &Cluster,
        plan: &SplitPlan,
    ) -> SimResult {
        assert_eq!(layers.len(), self.n_models());
        assert_eq!(cluster.len(), self.n_gpus());
        let projected: Vec<MoeLayerStats> = layers
            .iter()
            .enumerate()
            .map(|(m, l)| self.project_layer_split(m, l, plan))
            .collect();
        let refs: Vec<&MoeLayerStats> = projected.iter().collect();
        simulate_group(&refs, cluster, self.base.policy).0
    }

    /// [`ReplicatedDeployment::simulate_layer`] on a network topology —
    /// collectives priced by [`crate::schedule::comm_time_on`]. Big switch ⇒
    /// identical to [`ReplicatedDeployment::simulate_layer`]. Panics when a
    /// two-tier grouping does not fit `cluster`.
    pub fn simulate_layer_topology(
        &self,
        layers: &[&MoeLayerStats],
        cluster: &Cluster,
        topo: &Topology,
        plan: &SplitPlan,
    ) -> SimResult {
        assert_eq!(layers.len(), self.n_models());
        assert_eq!(cluster.len(), self.n_gpus());
        let projected: Vec<MoeLayerStats> = layers
            .iter()
            .enumerate()
            .map(|(m, l)| self.project_layer_split(m, l, plan))
            .collect();
        let refs: Vec<&MoeLayerStats> = projected.iter().collect();
        simulate_group_topology(&refs, cluster, topo, self.base.policy).0
    }

    /// [`ReplicatedDeployment::simulate`] on a network topology, layer by
    /// layer.
    pub fn simulate_topology(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
        plan: &SplitPlan,
    ) -> Vec<SimResult> {
        assert_eq!(traces.len(), self.n_models());
        let n_layers = traces[0].layers.len();
        for t in traces {
            assert_eq!(t.layers.len(), n_layers, "traces must have equal layer counts");
        }
        (0..n_layers)
            .map(|k| {
                let layers: Vec<&MoeLayerStats> = traces.iter().map(|t| &t.layers[k]).collect();
                self.simulate_layer_topology(&layers, cluster, topo, plan)
            })
            .collect()
    }

    /// Simulate full traces layer by layer under one split plan.
    pub fn simulate(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        plan: &SplitPlan,
    ) -> Vec<SimResult> {
        assert_eq!(traces.len(), self.n_models());
        let n_layers = traces[0].layers.len();
        for t in traces {
            assert_eq!(t.layers.len(), n_layers, "traces must have equal layer counts");
        }
        (0..n_layers)
            .map(|k| {
                let layers: Vec<&MoeLayerStats> = traces.iter().map(|t| &t.layers[k]).collect();
                self.simulate_layer(&layers, cluster, plan)
            })
            .collect()
    }

    /// Total simulated inference time across all layers (ms).
    pub fn total_inference_ms(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        plan: &SplitPlan,
    ) -> f64 {
        self.simulate(traces, cluster, plan)
            .iter()
            .map(|r| r.inference_ms)
            .sum()
    }

    /// JSON rendering: the base deployment's fields plus the replica sets.
    pub fn to_json(&self) -> Json {
        let replicas = Json::Arr(
            self.replicas
                .iter()
                .map(|model| {
                    Json::Arr(
                        model
                            .iter()
                            .map(|set| {
                                Json::Arr(set.iter().map(|&g| Json::from(g)).collect())
                            })
                            .collect(),
                    )
                })
                .collect(),
        );
        let mut json = self.base.to_json();
        if let Json::Obj(map) = &mut json {
            map.insert("replicas".to_string(), replicas);
            map.insert(
                "added_replicas".to_string(),
                Json::from(self.added_replicas()),
            );
        }
        json
    }
}

/// Per-GPU completion estimates under a replicated deployment and split
/// plan — [`crate::placement::estimate_per_gpu`] with split projection:
/// serialized compute of every hosted copy's token share plus the GPU's
/// worst-direction share of the aggregated split wire volume.
pub fn estimate_per_gpu_replicated(
    rep: &ReplicatedDeployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    plan: &SplitPlan,
) -> Vec<f64> {
    assert_eq!(layers.len(), rep.n_models());
    assert_eq!(cluster.len(), rep.n_gpus());
    let n = rep.n_gpus();

    let mut compute = vec![0.0f64; n];
    let mut agg = TrafficMatrix::zeros(n);
    for (m, layer) in layers.iter().enumerate() {
        let proj = rep.project_layer_split(m, layer, plan).traffic;
        let loads = proj.expert_loads();
        for (g, c) in compute.iter_mut().enumerate() {
            *c += layer.gate_ms + layer.agg_ms + loads[g] as f64 * layer.ffn_ms_per_token;
        }
        agg = agg.sum(&proj);
    }

    (0..n)
        .map(|g| {
            let gpu = cluster.gpu(g);
            let wire = agg.row_sum(g).max(agg.col_sum(g)) as f64 / gpu.bandwidth;
            compute[g] / gpu.flops_scale + wire
        })
        .collect()
}

/// The combined bottleneck objective of a replicated plan on a topology in
/// **one projection pass**: the split-aware per-GPU completion bottleneck
/// joined with the cross-uplink drain of the same aggregated split traffic.
/// Computing both through [`estimate_per_gpu_replicated`] +
/// [`ReplicatedDeployment::aggregated_traffic_split`] projects every model
/// twice; this derives both from a single aggregate — same values, half the
/// work. On [`Topology::BigSwitch`] it equals
/// [`estimate_bottleneck_replicated`]. The planner's greedy goes further
/// still ([`ReplicaDeltaEstimator`] prices candidates by delta); this is the
/// from-scratch form for one-shot callers (the coordinator's replan gate,
/// the planner's refinement guard).
pub fn estimate_objective_on(
    rep: &ReplicatedDeployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
    plan: &SplitPlan,
) -> f64 {
    assert_eq!(layers.len(), rep.n_models());
    assert_eq!(cluster.len(), rep.n_gpus());
    let n = rep.n_gpus();
    let mut compute = vec![0.0f64; n];
    let mut agg = TrafficMatrix::zeros(n);
    for (m, layer) in layers.iter().enumerate() {
        let proj = rep.project_layer_split(m, layer, plan).traffic;
        let loads = proj.expert_loads();
        for (g, c) in compute.iter_mut().enumerate() {
            *c += layer.gate_ms + layer.agg_ms + loads[g] as f64 * layer.ffn_ms_per_token;
        }
        agg = agg.sum(&proj);
    }
    let mut mx = 0.0f64;
    for g in 0..n {
        let gpu = cluster.gpu(g);
        let wire = agg.row_sum(g).max(agg.col_sum(g)) as f64 / gpu.bandwidth;
        mx = mx.max(compute[g] / gpu.flops_scale + wire);
    }
    if !matches!(topo, Topology::BigSwitch) {
        mx = mx.max(uplink_bound(&agg, cluster, topo));
    }
    mx
}

/// Max over [`estimate_per_gpu_replicated`] — the objective the replication
/// pass and the split-aware refinement minimize.
pub fn estimate_bottleneck_replicated(
    rep: &ReplicatedDeployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    plan: &SplitPlan,
) -> f64 {
    estimate_per_gpu_replicated(rep, layers, cluster, plan)
        .into_iter()
        .fold(0.0, f64::max)
}

/// Split-aware swap/move refinement: the planner's local search re-run after
/// replication. Primaries move (or swap) between GPUs whenever that shrinks
/// the split-aware bottleneck estimate; every candidate re-optimizes the
/// split plan, so a move is judged by the best splits it enables. Moves onto
/// a GPU that already holds another replica of the same expert are skipped
/// (the set must stay duplicate-free), and with a positive `slots_per_gpu`
/// budget a move never pushes a GPU past it (swaps keep per-GPU occupancy
/// unchanged, so they are always budget-safe). Bounded rounds, hot-GPU
/// pruning — a candidate not touching a bottleneck GPU cannot shrink the
/// max.
pub fn refine_replicated(
    rep: &mut ReplicatedDeployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    slots_per_gpu: usize,
) {
    let n = rep.n_gpus();
    let units: Vec<(usize, usize)> = (0..rep.n_models())
        .flat_map(|m| (0..rep.base.n_experts(m)).map(move |e| (m, e)))
        .collect();

    let eval = |rep: &ReplicatedDeployment| -> (f64, Vec<f64>) {
        let plan = optimize_splits(rep, layers, cluster);
        let costs = estimate_per_gpu_replicated(rep, layers, cluster, &plan);
        let mx = costs.iter().cloned().fold(0.0, f64::max);
        (mx, costs)
    };
    let is_hot = |costs: &[f64], best: f64, g: usize| costs[g] >= best - 1e-9;

    let (mut best, mut costs) = eval(rep);
    // Occupancy cache: only moves change it (swaps are occupancy-neutral),
    // so it updates at commit points instead of being rebuilt per candidate.
    let mut slots = rep.slots_per_gpu();
    for _ in 0..4 {
        let mut improved = false;
        for &(m, e) in &units {
            let cur = rep.base.assignments[m][e];
            for g in 0..n {
                if g == cur
                    || rep.replicas[m][e].contains(&g)
                    || !(is_hot(&costs, best, cur) || is_hot(&costs, best, g))
                    || (slots_per_gpu > 0 && slots[g] >= slots_per_gpu)
                {
                    continue;
                }
                rep.base.assignments[m][e] = g;
                rep.replicas[m][e][0] = g;
                let (mx, c) = eval(rep);
                if mx + 1e-12 < best {
                    best = mx;
                    costs = c;
                    slots[cur] -= 1;
                    slots[g] += 1;
                    improved = true;
                    break; // unit committed; on to the next one
                }
                rep.base.assignments[m][e] = cur;
                rep.replicas[m][e][0] = cur;
            }
        }
        for i in 0..units.len() {
            for j in (i + 1)..units.len() {
                let (m1, e1) = units[i];
                let (m2, e2) = units[j];
                let g1 = rep.base.assignments[m1][e1];
                let g2 = rep.base.assignments[m2][e2];
                if g1 == g2
                    || rep.replicas[m1][e1].contains(&g2)
                    || rep.replicas[m2][e2].contains(&g1)
                    || !(is_hot(&costs, best, g1) || is_hot(&costs, best, g2))
                {
                    continue;
                }
                rep.base.assignments[m1][e1] = g2;
                rep.replicas[m1][e1][0] = g2;
                rep.base.assignments[m2][e2] = g1;
                rep.replicas[m2][e2][0] = g1;
                let (mx, c) = eval(rep);
                if mx + 1e-12 < best {
                    best = mx;
                    costs = c;
                    improved = true;
                } else {
                    rep.base.assignments[m1][e1] = g1;
                    rep.replicas[m1][e1][0] = g1;
                    rep.base.assignments[m2][e2] = g2;
                    rep.replicas[m2][e2][0] = g2;
                }
            }
        }
        if !improved {
            break;
        }
    }
    debug_assert!(
        ReplicatedDeployment::new(rep.base.clone(), rep.replicas.clone()).is_ok(),
        "refinement must preserve replica-set validity"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{estimate_bottleneck, Scenario};
    use crate::schedule::SchedulePolicy;
    use crate::traffic::zipf_traffic;

    fn hot_layer(n: usize, alpha: f64, seed: u64) -> MoeLayerStats {
        MoeLayerStats {
            traffic: zipf_traffic(n, 512, alpha, seed),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        }
    }

    fn packed_base(n_experts: usize, n_gpus: usize) -> Deployment {
        // expert e -> GPU e % n_gpus
        Deployment::new(
            n_gpus,
            vec![(0..n_experts).map(|e| e % n_gpus).collect()],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap()
    }

    #[test]
    fn validation_catches_bad_replica_maps() {
        let base = packed_base(4, 2);
        // wrong model count
        assert!(matches!(
            ReplicatedDeployment::new(base.clone(), vec![]),
            Err(ReplicationError::ShapeMismatch { .. })
        ));
        // empty set
        assert!(matches!(
            ReplicatedDeployment::new(
                base.clone(),
                vec![vec![vec![0], vec![1], vec![], vec![1]]]
            ),
            Err(ReplicationError::EmptyReplicaSet { model: 0, expert: 2 })
        ));
        // replica 0 must be the primary
        assert!(matches!(
            ReplicatedDeployment::new(
                base.clone(),
                vec![vec![vec![1], vec![1], vec![0], vec![1]]]
            ),
            Err(ReplicationError::PrimaryMismatch { model: 0, expert: 0 })
        ));
        // duplicate GPU in a set
        assert!(matches!(
            ReplicatedDeployment::new(
                base.clone(),
                vec![vec![vec![0, 0], vec![1], vec![0], vec![1]]]
            ),
            Err(ReplicationError::DuplicateReplica { gpu: 0, .. })
        ));
        // out of range
        let err = ReplicatedDeployment::new(
            base,
            vec![vec![vec![0, 5], vec![1], vec![0], vec![1]]],
        )
        .unwrap_err();
        assert!(matches!(err, ReplicationError::GpuOutOfRange { gpu: 5, .. }));
        assert!(err.to_string().contains("GPU 5"));
    }

    #[test]
    fn trivial_wrapper_is_not_replicated() {
        let rep = ReplicatedDeployment::from_deployment(packed_base(6, 3));
        assert!(!rep.is_replicated());
        assert_eq!(rep.added_replicas(), 0);
        assert_eq!(rep.slots_per_gpu(), vec![2, 2, 2]);
        assert_eq!(rep.replica_count(0, 0), 1);
    }

    #[test]
    fn trivial_projection_matches_base_bitwise() {
        let rep = ReplicatedDeployment::from_deployment(packed_base(8, 4));
        let plan = SplitPlan::trivial(&rep);
        let l = hot_layer(8, 1.2, 5);
        assert_eq!(
            rep.project_layer_split(0, &l, &plan),
            rep.base.project_layer(0, &l)
        );
        // estimates agree with the placement-core estimator too
        let cluster = Cluster::homogeneous(4, 100.0);
        let a = estimate_per_gpu_replicated(&rep, &[&l], &cluster, &plan);
        let b = crate::placement::estimate_per_gpu(&rep.base, &[&l], &cluster);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn replicating_the_hot_expert_cuts_the_bottleneck() {
        let n_gpus = 4;
        let l = hot_layer(8, 1.2, 9);
        let cluster = Cluster::homogeneous(n_gpus, 100.0);
        let base = packed_base(8, n_gpus);
        let hot = (0..8)
            .max_by_key(|&e| l.expert_loads()[e])
            .unwrap();
        let mut rep = ReplicatedDeployment::from_deployment(base.clone());
        for g in 0..n_gpus {
            if g != rep.base.gpu_of(0, hot) {
                rep.add_replica(0, hot, g).unwrap();
            }
        }
        let plan = optimize_splits(&rep, &[&l], &cluster);
        let replicated = estimate_bottleneck_replicated(&rep, &[&l], &cluster, &plan);
        let unreplicated = estimate_bottleneck(&base, &[&l], &cluster);
        assert!(
            replicated < unreplicated * 0.85,
            "replicated {replicated} vs unreplicated {unreplicated}"
        );
    }

    #[test]
    fn gpu_loads_split_conserves_tokens() {
        let mut rep = ReplicatedDeployment::from_deployment(packed_base(4, 2));
        rep.add_replica(0, 0, 1).unwrap();
        let plan = SplitPlan {
            weights: vec![vec![vec![0.5, 0.5], vec![1.0], vec![1.0], vec![1.0]]],
        };
        let hist = [100u64, 10, 20, 30];
        let loads = rep.gpu_loads_split(0, &hist, &plan);
        assert_eq!(loads.iter().sum::<u64>(), 160);
        // expert 0 (primary GPU 0) split 50/50: GPU 0 gets 50 + expert 2's 20
        assert_eq!(loads, vec![50 + 20, 50 + 10 + 30]);
    }

    #[test]
    fn refinement_never_worsens_and_stays_valid() {
        let l = hot_layer(8, 1.2, 11);
        let cluster = Cluster::homogeneous(4, 100.0);
        let mut rep = ReplicatedDeployment::from_deployment(packed_base(8, 4));
        let hot = (0..8).max_by_key(|&e| l.expert_loads()[e]).unwrap();
        rep.add_replica(0, hot, (rep.base.gpu_of(0, hot) + 1) % 4).unwrap();
        let before = {
            let plan = optimize_splits(&rep, &[&l], &cluster);
            estimate_bottleneck_replicated(&rep, &[&l], &cluster, &plan)
        };
        refine_replicated(&mut rep, &[&l], &cluster, 0);
        let after = {
            let plan = optimize_splits(&rep, &[&l], &cluster);
            estimate_bottleneck_replicated(&rep, &[&l], &cluster, &plan)
        };
        assert!(after <= before + 1e-9, "refine worsened {before} -> {after}");
        assert!(ReplicatedDeployment::new(rep.base.clone(), rep.replicas.clone()).is_ok());
    }

    #[test]
    fn evacuate_promotes_survivors_and_restores_sole_copies() {
        // 4 experts on 3 GPUs: expert 0 on {0,1}, expert 1 on {1}, expert 2
        // sole-hosted on GPU 1, expert 3 on {2,1}.
        let base = Deployment::new(
            3,
            vec![vec![1, 1, 1, 2]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let rep = ReplicatedDeployment::new(
            base,
            vec![vec![vec![1, 0], vec![1], vec![1], vec![2, 1]]],
        )
        .unwrap();
        let placeable = vec![true, false, true];
        let (out, promoted, restored) = rep.evacuate_gpu(1, &placeable);
        // no copy on GPU 1 survives, and every primary is its set's head
        for (e, set) in out.replicas[0].iter().enumerate() {
            assert!(!set.contains(&1));
            assert!(!set.is_empty());
            assert_eq!(out.base.assignments[0][e], set[0]);
        }
        // expert 0: survivor 0 promoted to primary
        assert_eq!(out.replicas[0][0], vec![0]);
        assert_eq!(out.base.assignments[0][0], 0);
        // experts 1 and 2: sole copies cold-restored onto placeable GPUs
        assert_eq!(restored, vec![(0, 1), (0, 2)]);
        // expert 3: replica dropped, primary 2 untouched
        assert_eq!(out.replicas[0][3], vec![2]);
        assert_eq!(out.base.assignments[0][3], 2);
        assert!(promoted.contains(&(0, 0)));
        // re-validation holds by construction
        assert!(ReplicatedDeployment::new(out.base.clone(), out.replicas.clone()).is_ok());
        // a second failure (experts 2 and 3 are now sole on GPU 2) restores
        // both onto the survivors
        let placeable2 = vec![true, true, false];
        let (next, p2, r2) = out.evacuate_gpu(2, &placeable2);
        assert!(p2.is_empty(), "sole copies restore, they do not promote");
        assert_eq!(r2, vec![(0, 2), (0, 3)]);
        for set in &next.replicas[0] {
            assert!(!set.contains(&2));
        }
    }

    #[test]
    #[should_panic]
    fn evacuate_requires_the_gpu_to_be_masked() {
        let rep = ReplicatedDeployment::from_deployment(packed_base(4, 2));
        rep.evacuate_gpu(0, &[true, true]);
    }

    #[test]
    fn json_includes_replica_sets() {
        let mut rep = ReplicatedDeployment::from_deployment(packed_base(4, 2));
        rep.add_replica(0, 1, 0).unwrap();
        let j = rep.to_json();
        assert_eq!(j.get("added_replicas").unwrap().as_u64(), Some(1));
        let sets = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(sets.len(), 1);
        assert_eq!(sets[0].as_arr().unwrap().len(), 4);
    }
}
