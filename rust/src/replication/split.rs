//! Fractional token-split optimization across expert replicas.
//!
//! Once an expert has several replicas, every sender must decide what
//! fraction of its tokens goes to each copy. [`optimize_splits`] makes that
//! decision by **water-filling** on a per-GPU completion level: experts are
//! visited heaviest first, and each expert's load is poured across its
//! replica GPUs so that their projected levels equalize — the continuous
//! analogue of the Theorem 5.1 sorted assignment, applied within one
//! expert's replica set. Levels charge both compute (FFN ms per token,
//! scaled by the GPU's speed) and wire (one receive-port token), so fast
//! well-connected GPUs absorb more of the split.
//!
//! The result is a [`SplitPlan`]: one weight vector per `(model, expert)`,
//! consumed by [`crate::traffic::TrafficMatrix::project_split`] at planning
//! time and by the serving router at inference time. Singleton replica sets
//! always get the weight vector `[1.0]`, which keeps un-replicated
//! deployments bit-for-bit identical to the plain placement path.

use super::ReplicatedDeployment;
use crate::cluster::Cluster;
use crate::sim::MoeLayerStats;

/// Fractional routing weights for every `(model, expert)`'s replica set.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitPlan {
    /// `weights[m][e][r]` = fraction of each sender's tokens for model `m`'s
    /// expert `e` routed to replica `r` (replica order matches
    /// [`ReplicatedDeployment::replicas`]). Each vector sums to 1.
    pub weights: Vec<Vec<Vec<f64>>>,
}

impl SplitPlan {
    /// The primary-only plan: every expert routes all tokens to replica 0.
    /// For un-replicated deployments this is also the *optimal* plan.
    pub fn trivial(rep: &ReplicatedDeployment) -> SplitPlan {
        let weights = rep
            .replicas
            .iter()
            .map(|model| {
                model
                    .iter()
                    .map(|set| {
                        let mut w = vec![0.0; set.len()];
                        w[0] = 1.0;
                        w
                    })
                    .collect()
            })
            .collect();
        SplitPlan { weights }
    }

    /// Weight vector of model `m`'s expert `e`.
    pub fn weights_for(&self, m: usize, e: usize) -> &[f64] {
        &self.weights[m][e]
    }
}

/// Marginal cost (ms) of routing one more token to a copy of an expert of
/// `layer` hosted on GPU `g`: FFN compute plus one receive-port token. The
/// wire charge is an upper bound (tokens from the replica's own GPU stay
/// local), which biases splits toward under-loading slow ports — the safe
/// direction.
fn token_cost(layer: &MoeLayerStats, cluster: &Cluster, g: usize) -> f64 {
    let gpu = cluster.gpu(g);
    layer.ffn_ms_per_token / gpu.flops_scale + 1.0 / gpu.bandwidth
}

/// Water-filling: pour `total` load over replicas with current `levels` and
/// per-unit `costs`, returning per-replica allocations that equalize the
/// resulting levels (replicas already above the water line get nothing).
fn water_fill(total: f64, levels: &[f64], costs: &[f64]) -> Vec<f64> {
    let k = levels.len();
    debug_assert_eq!(k, costs.len());
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| levels[a].partial_cmp(&levels[b]).unwrap().then(a.cmp(&b)));

    // With the `p` lowest replicas active at water level `T`:
    // Σ_{r active} (T − L_r) / c_r = total  ⇒  T = (total + Σ L_r/c_r) / Σ 1/c_r.
    // The first prefix whose `T` does not rise above the next replica's
    // level is the solution (the standard water-filling argument).
    let mut sum_lc = 0.0;
    let mut sum_ic = 0.0;
    let mut t_opt = 0.0;
    let mut active = k;
    for p in 1..=k {
        let r = order[p - 1];
        sum_lc += levels[r] / costs[r];
        sum_ic += 1.0 / costs[r];
        let t = (total + sum_lc) / sum_ic;
        let next = if p < k { levels[order[p]] } else { f64::INFINITY };
        if t <= next {
            t_opt = t;
            active = p;
            break;
        }
        t_opt = t;
    }

    let mut out = vec![0.0; k];
    for &r in order.iter().take(active) {
        out[r] = ((t_opt - levels[r]) / costs[r]).max(0.0);
    }
    // Remove floating-point drift so allocations sum to exactly `total`.
    let s: f64 = out.iter().sum();
    if s > 0.0 {
        for x in &mut out {
            *x *= total / s;
        }
    } else {
        out[order[0]] = total;
    }
    out
}

/// Compute split weights for `rep` on one layer set (one GPU-level plan per
/// model; `layers[m]` must be **expert-indexed** statistics of model `m`).
///
/// Experts are processed heaviest first. Each singleton expert charges its
/// full load to its primary's level; each replicated expert water-fills its
/// load across its replica GPUs' levels. Deterministic: ties break on
/// `(model, expert)` order.
pub fn optimize_splits(
    rep: &ReplicatedDeployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
) -> SplitPlan {
    assert_eq!(layers.len(), rep.n_models(), "one layer per model");
    assert_eq!(cluster.len(), rep.n_gpus());
    let loads: Vec<Vec<u64>> = layers.iter().map(|l| l.expert_loads()).collect();
    solve_splits(&rep.replicas, None, &loads, layers, cluster)
}

/// The water-filling core behind [`optimize_splits`], operating on raw
/// replica sets so the incremental planner
/// ([`super::ReplicaDeltaEstimator`]) can solve candidate plans without
/// materializing a mutated [`ReplicatedDeployment`] — and without
/// recomputing `expert_loads` (O(experts²)) on every call.
///
/// `override_set` substitutes one `(model, expert)`'s replica set, which is
/// how a tentative "add replica `g` to `(m, e)`" candidate is priced. With
/// `None` this is exactly the [`optimize_splits`] computation: same visit
/// order, same floating-point operations, bit-for-bit identical weights.
pub(crate) fn solve_splits(
    sets: &[Vec<Vec<usize>>],
    override_set: Option<(usize, usize, &[usize])>,
    loads: &[Vec<u64>],
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
) -> SplitPlan {
    let n = cluster.len();
    let set_of = |m: usize, e: usize| -> &[usize] {
        match override_set {
            Some((om, oe, s)) if om == m && oe == e => s,
            _ => sets[m][e].as_slice(),
        }
    };

    // Per-GPU water level, seeded with the constant per-model compute terms
    // so slower GPUs start higher.
    let mut level = vec![0.0f64; n];
    for (g, l) in level.iter_mut().enumerate() {
        let flops = cluster.gpu(g).flops_scale;
        for layer in layers {
            *l += (layer.gate_ms + layer.agg_ms) / flops;
        }
    }

    // The trivial (primary-only) plan, shaped by the effective sets.
    let mut plan = SplitPlan {
        weights: (0..sets.len())
            .map(|m| {
                (0..sets[m].len())
                    .map(|e| {
                        let mut w = vec![0.0; set_of(m, e).len()];
                        w[0] = 1.0;
                        w
                    })
                    .collect()
            })
            .collect(),
    };

    // Pass 1: singleton (and zero-load) experts are not a decision — charge
    // their full load to their primary's level up front, so every split
    // below sees the fixed load landscape.
    let mut replicated: Vec<(usize, usize)> = Vec::new();
    for m in 0..sets.len() {
        for e in 0..sets[m].len() {
            let set = set_of(m, e);
            if set.len() == 1 || loads[m][e] == 0 {
                level[set[0]] += loads[m][e] as f64 * token_cost(layers[m], cluster, set[0]);
            } else {
                replicated.push((m, e));
            }
        }
    }

    // Pass 2: water-fill the replicated experts, heaviest first.
    replicated.sort_by_key(|&(m, e)| (std::cmp::Reverse(loads[m][e]), m, e));
    for (m, e) in replicated {
        let set = set_of(m, e);
        let load = loads[m][e] as f64;
        let costs: Vec<f64> = set
            .iter()
            .map(|&g| token_cost(layers[m], cluster, g))
            .collect();
        let cur: Vec<f64> = set.iter().map(|&g| level[g]).collect();
        let alloc = water_fill(load, &cur, &costs);
        for (r, &x) in alloc.iter().enumerate() {
            plan.weights[m][e][r] = x / load;
            level[set[r]] += x * costs[r];
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{Deployment, Scenario};
    use crate::schedule::SchedulePolicy;
    use crate::traffic::TrafficMatrix;

    fn layer(n: usize, hot: usize, hot_tokens: u64) -> MoeLayerStats {
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, if j == hot { hot_tokens } else { 1 });
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        }
    }

    fn rep_with_hot_replicated(n: usize) -> ReplicatedDeployment {
        let base = Deployment::new(
            n,
            vec![(0..n).collect()],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let mut rep = ReplicatedDeployment::from_deployment(base);
        rep.add_replica(0, 0, 1).unwrap();
        rep.add_replica(0, 0, 2).unwrap();
        rep
    }

    #[test]
    fn water_fill_equalizes_levels() {
        let alloc = water_fill(90.0, &[0.0, 0.0, 0.0], &[1.0, 1.0, 1.0]);
        for a in &alloc {
            assert!((a - 30.0).abs() < 1e-9);
        }
        // a replica already above the water line gets nothing
        let alloc = water_fill(10.0, &[0.0, 100.0], &[1.0, 1.0]);
        assert!((alloc[0] - 10.0).abs() < 1e-9);
        assert_eq!(alloc[1], 0.0);
        // cheaper replicas absorb more
        let alloc = water_fill(30.0, &[0.0, 0.0], &[1.0, 2.0]);
        assert!(alloc[0] > alloc[1]);
        assert!((alloc[0] + alloc[1] - 30.0).abs() < 1e-9);
        // resulting levels equalize: a0 * 1 == a1 * 2
        assert!((alloc[0] - 2.0 * alloc[1]).abs() < 1e-9);
    }

    #[test]
    fn trivial_plan_is_primary_only() {
        let rep = rep_with_hot_replicated(4);
        let plan = SplitPlan::trivial(&rep);
        assert_eq!(plan.weights_for(0, 0), &[1.0, 0.0, 0.0]);
        assert_eq!(plan.weights_for(0, 1), &[1.0]);
    }

    #[test]
    fn optimized_splits_spread_the_hot_expert() {
        let rep = rep_with_hot_replicated(4);
        let l = layer(4, 0, 50);
        let cluster = crate::cluster::Cluster::homogeneous(4, 100.0);
        let plan = optimize_splits(&rep, &[&l], &cluster);
        let w = plan.weights_for(0, 0);
        assert_eq!(w.len(), 3);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // all three replicas carry a meaningful share of the hot expert
        for &x in w {
            assert!(x > 0.1, "weights {w:?}");
        }
        // singleton experts keep the trivial weight
        assert_eq!(plan.weights_for(0, 3), &[1.0]);
    }

    #[test]
    fn splits_favor_faster_gpus_on_hetero_clusters() {
        let rep = {
            let base = Deployment::new(
                4,
                vec![vec![0, 1, 2, 3]],
                SchedulePolicy::Aurora,
                Scenario::ExclusiveHeterogeneous,
            )
            .unwrap();
            let mut rep = ReplicatedDeployment::from_deployment(base);
            // replica of expert 0 (primary on fast GPU 0) on slow GPU 3
            rep.add_replica(0, 0, 3).unwrap();
            rep
        };
        let l = layer(4, 0, 200);
        let cluster = crate::cluster::Cluster::paper_heterogeneous(4, 100.0);
        let plan = optimize_splits(&rep, &[&l], &cluster);
        let w = plan.weights_for(0, 0);
        // GPU 0 (1.0 scale) outweighs GPU 3 (0.4 scale)
        assert!(w[0] > w[1], "weights {w:?}");
    }
}
