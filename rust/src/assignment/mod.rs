//! Expert → GPU assignment on heterogeneous clusters (paper §5).
//!
//! Theorem 5.1: sorting experts by token load (descending) and GPUs by
//! performance (descending) and pairing them in order minimizes inference
//! time. [`sorted_assignment`] implements it; [`random_assignment`] is the
//! RGA baseline of §8.1; [`brute_force_assignment`] enumerates all
//! permutations against an arbitrary cost function and is the optimality
//! oracle used by tests and the Fig. 13 harness.
//!
//! An assignment is a permutation `perm` with `perm[e] = GPU id hosting
//! expert e` (equivalently: the argument to
//! [`crate::traffic::TrafficMatrix::permute`]).

use crate::cluster::Cluster;
use crate::matching::for_each_permutation;
use crate::util::Rng;

/// Theorem 5.1: most-loaded expert onto the highest-performance GPU,
/// second-most-loaded onto the second-best, and so on.
///
/// `loads[e]` is the historical token load of expert `e` (its FFN input
/// volume, which also upper-bounds its network volume in the paper's model).
pub fn sorted_assignment(loads: &[u64], cluster: &Cluster) -> Vec<usize> {
    assert_eq!(loads.len(), cluster.len(), "one expert per GPU");
    let mut experts: Vec<usize> = (0..loads.len()).collect();
    // descending load; stable tiebreak on expert id for determinism
    experts.sort_by_key(|&e| (std::cmp::Reverse(loads[e]), e));
    let gpus = cluster.ids_by_perf_desc();
    let mut perm = vec![0usize; loads.len()];
    for (rank, &e) in experts.iter().enumerate() {
        perm[e] = gpus[rank];
    }
    perm
}

/// RGA baseline: a uniformly random expert→GPU bijection.
pub fn random_assignment(n: usize, rng: &mut Rng) -> Vec<usize> {
    rng.permutation(n)
}

/// Exhaustive assignment search minimizing `cost(perm)`. `O(n!)` — use only
/// for small `n` (tests, Fig. 13 optimum).
pub fn brute_force_assignment(
    n: usize,
    mut cost: impl FnMut(&[usize]) -> f64,
) -> (f64, Vec<usize>) {
    let mut best = f64::INFINITY;
    let mut best_perm: Vec<usize> = (0..n).collect();
    for_each_permutation(n, |perm| {
        let c = cost(perm);
        if c < best {
            best = c;
            best_perm = perm.to_vec();
        }
    });
    (best, best_perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuSpec;

    fn hetero4() -> Cluster {
        Cluster::new(vec![
            GpuSpec {
                flops_scale: 0.4,
                bandwidth: 0.4,
            },
            GpuSpec {
                flops_scale: 1.0,
                bandwidth: 1.0,
            },
            GpuSpec {
                flops_scale: 0.5,
                bandwidth: 0.5,
            },
            GpuSpec {
                flops_scale: 0.8,
                bandwidth: 0.8,
            },
        ])
    }

    #[test]
    fn heaviest_expert_gets_best_gpu() {
        let c = hetero4();
        let loads = vec![10, 40, 20, 30];
        let perm = sorted_assignment(&loads, &c);
        assert_eq!(perm[1], 1); // heaviest -> 1.0 GPU
        assert_eq!(perm[3], 3); // next -> 0.8 GPU
        assert_eq!(perm[2], 2); // next -> 0.5 GPU
        assert_eq!(perm[0], 0); // lightest -> 0.4 GPU
    }

    #[test]
    fn assignment_is_bijection() {
        let c = Cluster::paper_heterogeneous(8, 1.0);
        let loads = vec![5, 5, 5, 9, 1, 5, 5, 5]; // ties exercise the tiebreak
        let perm = sorted_assignment(&loads, &c);
        let mut seen = vec![false; 8];
        for &g in &perm {
            assert!(!seen[g]);
            seen[g] = true;
        }
    }

    #[test]
    fn ties_are_deterministic() {
        let c = Cluster::paper_heterogeneous(8, 1.0);
        let loads = vec![3; 8];
        assert_eq!(sorted_assignment(&loads, &c), sorted_assignment(&loads, &c));
    }

    #[test]
    fn random_assignment_is_bijection() {
        let mut rng = Rng::new(5);
        let perm = random_assignment(10, &mut rng);
        let mut seen = vec![false; 10];
        for &g in &perm {
            assert!(!seen[g]);
            seen[g] = true;
        }
    }

    #[test]
    fn brute_force_finds_known_optimum() {
        // cost = displacement from identity
        let (c, perm) = brute_force_assignment(5, |p| {
            p.iter()
                .enumerate()
                .map(|(i, &g)| (i as f64 - g as f64).abs())
                .sum()
        });
        assert_eq!(c, 0.0);
        assert_eq!(perm, vec![0, 1, 2, 3, 4]);
    }

    /// Theorem 5.1 optimality on the bottleneck objective: the sorted
    /// assignment minimizes max_i (load of expert on GPU i / perf of GPU i).
    #[test]
    fn sorted_assignment_minimizes_bottleneck_objective() {
        let mut rng = Rng::new(0x7531);
        for _ in 0..20 {
            let c = hetero4();
            let loads: Vec<u64> = (0..4).map(|_| rng.gen_range(100) + 1).collect();
            let objective = |perm: &[usize]| -> f64 {
                (0..4)
                    .map(|e| loads[e] as f64 / c.gpu(perm[e]).flops_scale)
                    .fold(0.0, f64::max)
            };
            let sorted = sorted_assignment(&loads, &c);
            let (best, _) = brute_force_assignment(4, |p| objective(p));
            assert!(
                objective(&sorted) <= best + 1e-9,
                "loads={loads:?} sorted={sorted:?}"
            );
        }
    }
}
