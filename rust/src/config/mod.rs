//! Configuration: JSON config files and physical-unit conversion.
//!
//! The simulator works in *tokens* and *tokens per millisecond*; configs
//! speak Gbps and bytes. [`gbps_to_tokens_per_ms`] converts, with an
//! `efficiency` factor capturing real all-to-all goodput (small messages,
//! incast, protocol overhead — the reason the paper sees >60% of inference
//! time in communication on 100 Gbps fabric).

use crate::cluster::{Cluster, GpuSpec};
use crate::util::Json;

/// Bytes one token occupies on the wire (f32 activations of ViT-B's
/// d_model = 768).
pub const DEFAULT_TOKEN_BYTES: f64 = 768.0 * 4.0;

/// Default effective fraction of line rate an all-to-all achieves.
pub const DEFAULT_NET_EFFICIENCY: f64 = 0.2;

/// Convert a line rate in Gbps to simulator bandwidth (tokens/ms).
pub fn gbps_to_tokens_per_ms(gbps: f64, token_bytes: f64, efficiency: f64) -> f64 {
    assert!(gbps > 0.0 && token_bytes > 0.0 && (0.0..=1.0).contains(&efficiency));
    gbps * 1e9 * efficiency / 8.0 / token_bytes / 1e3
}

/// Experiment configuration (defaults reproduce §8.1).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalConfig {
    /// Number of experts per model == GPUs in the cluster.
    pub n_experts: usize,
    /// MoE layers per model.
    pub n_layers: usize,
    /// Images per batch driving the trace generator.
    pub batch_images: u64,
    /// Homogeneous line rate (Gbps).
    pub homo_gbps: f64,
    /// Heterogeneous line rates (Gbps), one group per entry.
    pub hetero_gbps: Vec<f64>,
    /// Wire bytes per token.
    pub token_bytes: f64,
    /// Effective all-to-all efficiency.
    pub net_efficiency: f64,
    /// RNG seed for traces and randomized baselines.
    pub seed: u64,
    /// Samples to average for randomized baselines (RCS/REC/RGA).
    pub baseline_samples: usize,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            n_experts: 8,
            n_layers: 4,
            batch_images: 64,
            homo_gbps: 100.0,
            hetero_gbps: vec![100.0, 80.0, 50.0, 40.0],
            token_bytes: DEFAULT_TOKEN_BYTES,
            net_efficiency: DEFAULT_NET_EFFICIENCY,
            seed: 2024,
            baseline_samples: 10,
        }
    }
}

impl EvalConfig {
    /// Homogeneous cluster in simulator units.
    pub fn homogeneous_cluster(&self) -> Cluster {
        Cluster::homogeneous(
            self.n_experts,
            gbps_to_tokens_per_ms(self.homo_gbps, self.token_bytes, self.net_efficiency),
        )
    }

    /// Heterogeneous cluster (§8.1): equal-sized GPU type groups; compute
    /// scale tracks bandwidth fraction (paper footnote 2 alignment).
    pub fn heterogeneous_cluster(&self) -> Cluster {
        let groups = self.hetero_gbps.len();
        assert!(
            self.n_experts % groups == 0,
            "GPU count must split evenly across types"
        );
        let top = self.hetero_gbps.iter().cloned().fold(f64::MIN, f64::max);
        let mut gpus = Vec::with_capacity(self.n_experts);
        for &gbps in &self.hetero_gbps {
            for _ in 0..self.n_experts / groups {
                gpus.push(GpuSpec {
                    flops_scale: gbps / top,
                    bandwidth: gbps_to_tokens_per_ms(gbps, self.token_bytes, self.net_efficiency),
                });
            }
        }
        Cluster::new(gpus)
    }

    /// Parse from JSON, starting from defaults (all fields optional).
    pub fn from_json(v: &Json) -> Result<EvalConfig, String> {
        let mut c = EvalConfig::default();
        if let Some(x) = v.get("n_experts").and_then(|x| x.as_u64()) {
            c.n_experts = x as usize;
        }
        if let Some(x) = v.get("n_layers").and_then(|x| x.as_u64()) {
            c.n_layers = x as usize;
        }
        if let Some(x) = v.get("batch_images").and_then(|x| x.as_u64()) {
            c.batch_images = x;
        }
        if let Some(x) = v.get("homo_gbps").and_then(|x| x.as_f64()) {
            c.homo_gbps = x;
        }
        if let Some(arr) = v.get("hetero_gbps").and_then(|x| x.as_arr()) {
            let mut rates = Vec::new();
            for e in arr {
                rates.push(e.as_f64().ok_or("hetero_gbps entries must be numbers")?);
            }
            if rates.is_empty() {
                return Err("hetero_gbps must be non-empty".into());
            }
            c.hetero_gbps = rates;
        }
        if let Some(x) = v.get("token_bytes").and_then(|x| x.as_f64()) {
            c.token_bytes = x;
        }
        if let Some(x) = v.get("net_efficiency").and_then(|x| x.as_f64()) {
            c.net_efficiency = x;
        }
        if let Some(x) = v.get("seed").and_then(|x| x.as_u64()) {
            c.seed = x;
        }
        if let Some(x) = v.get("baseline_samples").and_then(|x| x.as_u64()) {
            c.baseline_samples = x as usize;
        }
        if c.n_experts < 2 {
            return Err("n_experts must be >= 2".into());
        }
        if c.n_layers == 0 {
            return Err("n_layers must be >= 1".into());
        }
        Ok(c)
    }

    /// Load a config file, or defaults when `path` is `None`.
    pub fn load(path: Option<&str>) -> Result<EvalConfig, String> {
        match path {
            None => Ok(EvalConfig::default()),
            Some(p) => {
                let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
                let v = Json::parse(&text).map_err(|e| format!("{p}: {e}"))?;
                EvalConfig::from_json(&v)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversion_sane() {
        // 100 Gbps, 3072-byte tokens, 20% efficiency => ~814 tokens/ms
        let t = gbps_to_tokens_per_ms(100.0, 3072.0, 0.2);
        assert!((t - 813.8).abs() < 1.0, "t={t}");
    }

    #[test]
    fn default_clusters_have_expected_shape() {
        let c = EvalConfig::default();
        let homo = c.homogeneous_cluster();
        assert_eq!(homo.len(), 8);
        assert!(homo.is_homogeneous());
        let het = c.heterogeneous_cluster();
        assert_eq!(het.len(), 8);
        assert!(!het.is_homogeneous());
        // fastest group is 2.5x the slowest (100 vs 40 Gbps)
        let bws = het.bandwidths();
        let max = bws.iter().cloned().fold(f64::MIN, f64::max);
        let min = bws.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max / min - 2.5).abs() < 1e-9);
    }

    #[test]
    fn from_json_overrides_fields() {
        let v = Json::parse(r#"{"n_experts": 16, "seed": 7, "homo_gbps": 50}"#).unwrap();
        let c = EvalConfig::from_json(&v).unwrap();
        assert_eq!(c.n_experts, 16);
        assert_eq!(c.seed, 7);
        assert_eq!(c.homo_gbps, 50.0);
        assert_eq!(c.n_layers, 4); // default preserved
    }

    #[test]
    fn from_json_rejects_bad_values() {
        for bad in [
            r#"{"n_experts": 1}"#,
            r#"{"n_layers": 0}"#,
            r#"{"hetero_gbps": []}"#,
            r#"{"hetero_gbps": ["x"]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(EvalConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(EvalConfig::load(Some("/nonexistent/x.json")).is_err());
        assert!(EvalConfig::load(None).is_ok());
    }
}
