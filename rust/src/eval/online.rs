//! Online-coordination extension figure: serving a drifting workload with a
//! static plan vs periodic replanning vs the cost-aware coordinator vs a
//! zero-cost oracle.
//!
//! The workload is the drifting-Zipf stream of
//! [`crate::coordinator::online`]: expert popularity is Zipf(α) with the hot
//! expert rotating every few windows and per-window multinomial sampling
//! noise (live batches fluctuate). All four strategies start from the same
//! replicated plan, optimized for the first regime:
//!
//! * **static** decays every time the hot expert moves off its replicas;
//! * **periodic** (replan-every-window) chases the noise and pays a weight
//!   migration for nearly every window;
//! * **coordinator** smooths (EWMA), gates on drift, and replans only when
//!   the predicted gain clears the migration makespan — the win the figure
//!   pins;
//! * **oracle** replans per window with perfect knowledge at zero cost (the
//!   unreachable floor).

use super::report::Report;
use crate::config::EvalConfig;
use crate::coordinator::online::{run_online, OnlineConfig, OnlineStrategy};

/// Total serving time, tail latency, and replan/migration accounting of the
/// four strategies on the config's homogeneous cluster, serving one
/// `2 × n_experts`-expert model under a rotating Zipf(`alpha`) workload.
pub fn online_comparison(
    cfg: &EvalConfig,
    alpha: f64,
    windows: usize,
    rotate_every: usize,
) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let ocfg = OnlineConfig::from_eval(cfg, alpha, windows, rotate_every, true);

    let mut report = Report::new(
        &format!(
            "Online serving, drifting Zipf({alpha:.1}): {} experts on {} GPUs, {windows} windows (rotate every {rotate_every})",
            ocfg.n_experts,
            cluster.len()
        ),
        &[
            "total (ms)",
            "p95 window (ms)",
            "replans",
            "migration (ms)",
            "vs static",
        ],
    );

    let outcomes: Vec<_> = [
        OnlineStrategy::Static,
        OnlineStrategy::EveryWindow,
        OnlineStrategy::Coordinator,
        OnlineStrategy::Oracle,
    ]
    .into_iter()
    .map(|strategy| run_online(&ocfg, &cluster, strategy))
    .collect();
    let static_total = outcomes[0].total_ms;
    for out in &outcomes {
        report.row(
            out.strategy,
            vec![
                out.total_ms,
                out.p95_ms,
                out.replans as f64,
                out.migration_ms,
                static_total / out.total_ms,
            ],
        );
    }

    let vs_static = report
        .column("vs static")
        .expect("column was just added");
    // rows: static, periodic, coordinator, oracle
    report.note(format!(
        "coordinator {:.2}x faster than the static plan ({:.2}x for naive replan-every-window)",
        vs_static[2], vs_static[1]
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvalConfig {
        // 4-GPU cluster serving an 8-expert model; windows big enough that
        // one staging window amortizes well inside a rotation phase.
        EvalConfig {
            n_experts: 4,
            batch_images: 256,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn online_figure_shape_and_coordinator_win() {
        let cfg = small_cfg();
        let r = online_comparison(&cfg, 1.2, 16, 8);
        assert_eq!(r.rows.len(), 4);
        let labels: Vec<&str> = r.rows.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["static", "periodic", "coordinator", "oracle"]);
        let totals = r.column("total (ms)").unwrap();
        assert!(totals.iter().all(|&t| t > 0.0));
        let vs_static = r.column("vs static").unwrap();
        // the coordinator must not lose to the static plan (the stronger
        // coordinator-beats-naive contract is pinned at full scale in
        // rust/tests/integration_coordinator.rs, where tail-rank ties make
        // the naive strategy's churn structural)
        assert!(vs_static[2] >= 1.0, "{vs_static:?}");
        // static never replans; the coordinator replans at least once under
        // rotation and pays some migration
        let replans = r.column("replans").unwrap();
        assert_eq!(replans[0], 0.0);
        assert!(replans[2] >= 1.0, "{replans:?}");
    }

    #[test]
    fn stationary_uniform_keeps_every_strategy_close() {
        let cfg = small_cfg();
        let r = online_comparison(&cfg, 0.0, 8, 4);
        let replans = r.column("replans").unwrap();
        // uniform routing: the coordinator's drift gate never opens
        assert_eq!(replans[2], 0.0, "{replans:?}");
        let migration = r.column("migration (ms)").unwrap();
        assert_eq!(migration[2], 0.0);
    }
}
