//! The Lina baseline (§8.1, footnote 5): pack two experts of the *same*
//! model per GPU, pairing the most popular with the least popular.
//!
//! With two models and `n` experts each on `n` GPUs, Lina gives each model a
//! disjoint half of the cluster and runs it there with 2 experts per GPU.
//! The packed experts remain bound by their model's synchronous all-to-all
//! (Fig. 3a), which is exactly the inefficiency Aurora's cross-model
//! colocation removes.

use crate::cluster::Cluster;
use crate::colocation::lina_grouping;
use crate::schedule::SchedulePolicy;
use crate::sim::{simulate_exclusive, MoeLayerStats, SimResult};
use crate::trace::ModelTrace;

/// Merge a model's layer stats onto `n/2` GPUs using Lina's
/// popular-with-unpopular grouping (driven by the model's aggregate loads).
pub fn lina_merged_layers(trace: &ModelTrace) -> Vec<MoeLayerStats> {
    let groups = lina_grouping(&trace.total_expert_loads());
    trace
        .layers
        .iter()
        .map(|l| MoeLayerStats {
            traffic: l.traffic.merge_groups(&groups),
            ..*l
        })
        .collect()
}

/// Simulate one model under Lina on the GPUs listed in `gpu_ids` (a disjoint
/// half of `cluster`). Returns per-layer results.
pub fn lina_model_results(
    trace: &ModelTrace,
    cluster: &Cluster,
    gpu_ids: &[usize],
    policy: SchedulePolicy,
) -> Vec<SimResult> {
    let merged = lina_merged_layers(trace);
    assert_eq!(
        merged[0].traffic.n(),
        gpu_ids.len(),
        "Lina uses n/2 GPUs per model"
    );
    let sub = Cluster::new(gpu_ids.iter().map(|&g| cluster.gpu(g)).collect());
    merged
        .iter()
        .map(|l| simulate_exclusive(l, &sub, policy).0)
        .collect()
}

/// Lina per-layer inference times for a two-model deployment: model a on the
/// first half of `cluster`'s GPUs, model b on the second half. Returns
/// `(times_a, times_b)` in ms.
pub fn lina_colocated_times(
    a: &ModelTrace,
    b: &ModelTrace,
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> (Vec<f64>, Vec<f64>) {
    let n = cluster.len();
    let first: Vec<usize> = (0..n / 2).collect();
    let second: Vec<usize> = (n / 2..n).collect();
    let ra = lina_model_results(a, cluster, &first, policy);
    let rb = lina_model_results(b, cluster, &second, policy);
    (
        ra.iter().map(|r| r.inference_ms).collect(),
        rb.iter().map(|r| r.inference_ms).collect(),
    )
}

/// Mean GPU utilization across both models' halves, per layer.
pub fn lina_utilization(
    a: &ModelTrace,
    b: &ModelTrace,
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> Vec<f64> {
    let n = cluster.len();
    let first: Vec<usize> = (0..n / 2).collect();
    let second: Vec<usize> = (n / 2..n).collect();
    let ra = lina_model_results(a, cluster, &first, policy);
    let rb = lina_model_results(b, cluster, &second, policy);
    ra.iter()
        .zip(&rb)
        .map(|(x, y)| (x.utilization + y.utilization) / 2.0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvalConfig;
    use crate::eval::Workloads;

    #[test]
    fn merged_layers_halve_gpu_count_and_conserve_load() {
        let w = Workloads::generate(&EvalConfig::default());
        let merged = lina_merged_layers(&w.b16_coco);
        assert_eq!(merged[0].traffic.n(), 4);
        for (ml, ol) in merged.iter().zip(&w.b16_coco.layers) {
            assert_eq!(
                ml.traffic.expert_loads().iter().sum::<u64>(),
                ol.traffic.expert_loads().iter().sum::<u64>()
            );
        }
    }

    #[test]
    fn lina_times_positive_and_per_model() {
        let cfg = EvalConfig::default();
        let w = Workloads::generate(&cfg);
        let cluster = cfg.homogeneous_cluster();
        let (ta, tb) =
            lina_colocated_times(&w.b16_coco, &w.b32_coco, &cluster, SchedulePolicy::Aurora);
        assert_eq!(ta.len(), 4);
        assert_eq!(tb.len(), 4);
        assert!(ta.iter().all(|&t| t > 0.0));
        // B/16 moves 4x the tokens of B/32: its per-layer time should be larger
        assert!(ta[0] > tb[0]);
    }

    #[test]
    fn lina_utilization_in_unit_interval() {
        let cfg = EvalConfig::default();
        let w = Workloads::generate(&cfg);
        let cluster = cfg.homogeneous_cluster();
        for u in lina_utilization(&w.b16_coco, &w.b32_coco, &cluster, SchedulePolicy::Aurora) {
            assert!(u > 0.0 && u < 1.0);
        }
    }
}
