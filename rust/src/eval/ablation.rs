//! Ablation A1 — scheduling-policy sweep beyond the paper's baselines.
//!
//! DESIGN.md calls out two design questions the paper leaves implicit:
//!
//! 1. is Aurora's edge really the *receiver-contention* analysis, or would
//!    any bottleneck-aware order do? (LJF prioritizes heavy flows but
//!    ignores receivers);
//! 2. how does it compare to the structured, traffic-*oblivious* pairwise
//!    exchange of FasterMoE?
//!
//! This table answers both on the Exclusive + Homogeneous scenario.

use super::report::Report;
use super::workloads::Workloads;
use crate::config::EvalConfig;
use crate::schedule::SchedulePolicy;
use crate::sim::simulate_exclusive;
use crate::trace::{limoe_trace_topk, Dataset, LimoeVariant};
use crate::util::mean;

/// Ablation: per-layer inference time under five scheduling policies.
pub fn ablation_schedulers(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let mut r = Report::new(
        "Ablation A1: scheduler sweep (ms), Exclusive+Homogeneous",
        &["aurora", "ljf", "sjf", "pairwise", "rcs"],
    );
    let mut ratios: Vec<(String, Vec<f64>)> = vec![
        ("ljf".into(), vec![]),
        ("sjf".into(), vec![]),
        ("pairwise".into(), vec![]),
        ("rcs".into(), vec![]),
    ];
    for (name, trace) in w.singles() {
        for (k, layer) in trace.layers.iter().enumerate() {
            let run = |p: SchedulePolicy| simulate_exclusive(layer, &cluster, p).0.inference_ms;
            let a = run(SchedulePolicy::Aurora);
            let l = run(SchedulePolicy::Ljf);
            let s = run(SchedulePolicy::Sjf);
            let p = run(SchedulePolicy::Pairwise);
            let rcs_mean = mean(
                &(0..cfg.baseline_samples as u64)
                    .map(|i| {
                        run(SchedulePolicy::Rcs {
                            seed: cfg.seed.wrapping_add(i),
                        })
                    })
                    .collect::<Vec<_>>(),
            );
            ratios[0].1.push(l / a);
            ratios[1].1.push(s / a);
            ratios[2].1.push(p / a);
            ratios[3].1.push(rcs_mean / a);
            r.row(format!("{name}/L{}", k + 1), vec![a, l, s, p, rcs_mean]);
        }
    }
    for (name, rs) in &ratios {
        r.note(format!("{name}/aurora mean: {:.3}x", mean(rs)));
    }
    r
}

/// Ablation A2 — top-1 vs top-2 routing (§2.1: "one or two experts").
///
/// Top-2 doubles dispatched volume: both the all-to-alls and the expert FFNs
/// carry 2x tokens. The table quantifies the inference-time price and shows
/// Aurora's scheduling benefit persists (the b_max bound scales with the
/// traffic, the baselines' contention scales worse).
pub fn ablation_top2(cfg: &EvalConfig, _w: &Workloads) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let mut r = Report::new(
        "Ablation A2: top-1 vs top-2 routing (ms), Exclusive+Homogeneous",
        &["top1-aurora", "top2-aurora", "top2/top1", "top2-rcs", "rcs/aurora(top2)"],
    );
    for (vname, variant) in [("b16", LimoeVariant::B16), ("b32", LimoeVariant::B32)] {
        for (dname, dataset) in [("coco", Dataset::Coco), ("imagenet", Dataset::Imagenet)] {
            let t1 = limoe_trace_topk(
                variant, dataset, cfg.n_experts, 1, cfg.batch_images, cfg.seed, 1,
            );
            let t2 = limoe_trace_topk(
                variant, dataset, cfg.n_experts, 1, cfg.batch_images, cfg.seed, 2,
            );
            let a1 = simulate_exclusive(&t1.layers[0], &cluster, SchedulePolicy::Aurora)
                .0
                .inference_ms;
            let a2 = simulate_exclusive(&t2.layers[0], &cluster, SchedulePolicy::Aurora)
                .0
                .inference_ms;
            let rcs2 = mean(
                &(0..cfg.baseline_samples as u64)
                    .map(|i| {
                        simulate_exclusive(
                            &t2.layers[0],
                            &cluster,
                            SchedulePolicy::Rcs {
                                seed: cfg.seed.wrapping_add(i),
                            },
                        )
                        .0
                        .inference_ms
                    })
                    .collect::<Vec<_>>(),
            );
            r.row(
                format!("{vname}-{dname}"),
                vec![a1, a2, a2 / a1, rcs2, rcs2 / a2],
            );
        }
    }
    let blowup = r.column("top2/top1").expect("column was just added");
    r.note(format!(
        "top-2 costs {:.2}x top-1 on average (volume doubles; barriers amortize the rest)",
        mean(&blowup)
    ));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aurora_dominates_every_policy() {
        let cfg = EvalConfig {
            batch_images: 16,
            baseline_samples: 3,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        let r = ablation_schedulers(&cfg, &w);
        for col in ["ljf", "sjf", "pairwise", "rcs"] {
            for (v, a) in r.column(col).unwrap().iter().zip(r.column("aurora").unwrap()) {
                assert!(*v >= a - 1e-9, "{col}: {v} < aurora {a}");
            }
        }
    }

    #[test]
    fn top2_costs_more_but_less_than_double_compute_side() {
        let cfg = EvalConfig {
            batch_images: 16,
            baseline_samples: 3,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        let r = ablation_top2(&cfg, &w);
        for v in r.column("top2/top1").unwrap() {
            assert!((1.2..=2.2).contains(&v), "top2/top1 = {v}");
        }
        for v in r.column("rcs/aurora(top2)").unwrap() {
            assert!(v >= 1.0 - 1e-9, "aurora must keep winning under top-2");
        }
    }

    #[test]
    fn pairwise_never_beats_aurora_and_skew_costs_it() {
        // Pairwise exchange is contention-free, so on LIMoE-like traffic it
        // is a strong baseline (within a few % of optimal) — but it can never
        // beat the Theorem 4.2 bound, and on *adversarially* skewed traffic
        // (one hot flow per round) it pays the full sum of round maxima.
        let cfg = EvalConfig {
            batch_images: 32,
            baseline_samples: 3,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        let r = ablation_schedulers(&cfg, &w);
        let pairwise: f64 = r.column("pairwise").unwrap().iter().sum();
        let aurora: f64 = r.column("aurora").unwrap().iter().sum();
        assert!(pairwise >= aurora - 1e-9);

        // adversarial case: all traffic concentrated on one source row means
        // n-1 rounds each serialize one flow while the bottleneck *port*
        // bound (= row sum) could overlap nothing anyway — but concentrate a
        // hot flow per round and pairwise's makespan is the sum of hot flows
        // while b_max is just the hottest row/column.
        use crate::schedule::{comm_time, SchedulePolicy};
        use crate::traffic::TrafficMatrix;
        let n = 8;
        let mut d = TrafficMatrix::zeros(n);
        for k in 1..n {
            // round k's hot pair: (k, 2k mod n) carries 100, rest zero
            d.set(k, (2 * k) % n, 100);
        }
        let bw = vec![1.0; n];
        let pw = comm_time(&d, &bw, SchedulePolicy::Pairwise).makespan;
        let au = comm_time(&d, &bw, SchedulePolicy::Aurora).makespan;
        assert!(
            pw >= au * 2.0,
            "adversarial skew should hurt pairwise: {pw} vs {au}"
        );
    }
}
