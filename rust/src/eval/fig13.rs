//! Fig. 13 — optimality gap of the decoupled heuristic in the NP-hard
//! Colocating + Heterogeneous scenario.
//!
//! The "optimum" enumerates all `n!` pairings, solving the GPU-assignment
//! stage exactly per pairing and scoring with the full Table 2 timeline
//! (`colocation::hetero::brute_force_pairings`). The exact `n!²` double
//! enumeration is infeasible at the paper's n = 8; integration tests certify
//! the gap against the true double-exhaustive optimum at n ≤ 5.

use super::fig11::place_pair;
use super::report::Report;
use super::workloads::Workloads;
use crate::colocation::hetero::brute_force_pairings;
use crate::config::EvalConfig;
use crate::planner::{pair_gpu_cost, Planner};
use crate::sim::simulate_colocated;
use crate::util::mean;

/// Fig. 13 — Aurora vs brute-force optimum, per pair and layer.
pub fn fig13(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.heterogeneous_cluster();
    let planner = Planner::default();
    let mut r = Report::new(
        "Fig 13: Aurora vs optimum (ms), Colocating+Heterogeneous",
        &["aurora", "optimum", "ratio"],
    );
    let mut ratios = Vec::new();
    for (name, a, b) in w.pairs() {
        let t_aurora: Vec<f64> = (0..a.layers.len())
            .map(|k| {
                let plan = Planner {
                    planning_layer: k,
                    ..planner.clone()
                }
                .plan_colocated(a, b, &cluster);
                let ab = plan.assignment_b.clone().unwrap();
                simulate_colocated(
                    &a.layers[k].placed(&plan.assignment_a),
                    &b.layers[k].placed(&ab),
                    &cluster,
                    plan.policy,
                )
                .0
                .inference_ms
            })
            .collect();
        for k in 0..a.layers.len() {
            let la = &a.layers[k];
            let lb = &b.layers[k];
            let cost = pair_gpu_cost(la, lb, &cluster);
            let n = la.traffic.n();
            let (t_opt, _, _) = brute_force_pairings(n, &cost, |pi, sigma| {
                let (aa, abb) = place_pair(pi, sigma);
                simulate_colocated(
                    &la.placed(&aa),
                    &lb.placed(&abb),
                    &cluster,
                    crate::schedule::SchedulePolicy::Aurora,
                )
                .0
                .inference_ms
            });
            let ratio = t_aurora[k] / t_opt;
            ratios.push(ratio);
            r.row(format!("{name}/L{}", k + 1), vec![t_aurora[k], t_opt, ratio]);
        }
    }
    r.note(format!(
        "mean gap: {:.3}x (paper: 1.07x on average)",
        mean(&ratios)
    ));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full figure at reduced scale (n = 4 experts) to keep the exhaustive
    /// search fast in tests; the release harness runs n = 8.
    #[test]
    fn aurora_close_to_optimum_small_scale() {
        let cfg = EvalConfig {
            n_experts: 4,
            n_layers: 2,
            batch_images: 16,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        let r = fig13(&cfg, &w);
        for ratio in r.column("ratio").unwrap() {
            assert!(ratio >= 1.0 - 1e-9, "heuristic cannot beat the optimum");
            assert!(ratio < 1.5, "gap should be small, got {ratio}");
        }
    }
}
