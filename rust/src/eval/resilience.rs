//! Resilience extension figure: serving through a mid-trace GPU failure.
//!
//! The workload is **stationary** Zipf(α) — the hot expert never rotates, so
//! the injected [`crate::coordinator::ClusterEvent::GpuFailed`] is the only
//! disturbance and every latency excursion in the figure is attributable to
//! the failure and its repair. Three strategies serve the identical stream:
//!
//! * **static** — promotes around the failure (the survival minimum every
//!   strategy owes the workload) but never repairs: the degraded stopgap
//!   serves forever;
//! * **coordinator** — the full promote-then-repair pipeline of
//!   [`crate::coordinator::Coordinator::inject_event`]: survivors promoted in
//!   the failure window, a cost-aware repair replan staged right behind it;
//! * **oracle** — a fresh masked plan every window at zero migration cost:
//!   the fresh-plan-after-failure baseline the recovery win condition is
//!   measured against.
//!
//! The pinned contract (also enforced in
//! `rust/tests/integration_coordinator.rs`): no window ever routes a token to
//! the dead GPU, and the coordinator's serving latency recovers to within
//! **1.15×** of the oracle within **5 windows** of the failure.

use super::report::Report;
use crate::config::EvalConfig;
use crate::coordinator::online::{run_online, OnlineConfig, OnlineStrategy};
use crate::coordinator::ClusterEvent;

/// Windows after the failure within which recovery must land.
const RECOVERY_WINDOWS: usize = 5;
/// Recovered steady-state latency bound, relative to the fresh-plan oracle.
const RECOVERY_RATIO: f64 = 1.15;

/// Serving a stationary Zipf(`alpha`) workload for `windows` windows with
/// GPU 2 failing at the start of window `fail_window`, on the config's
/// homogeneous cluster. Reports total/p99/post-failure latencies per
/// strategy and each strategy's best post-failure ratio to the oracle.
pub fn resilience_comparison(
    cfg: &EvalConfig,
    alpha: f64,
    windows: usize,
    fail_window: usize,
) -> Report {
    assert!(fail_window < windows, "the failure must land inside the run");
    let cluster = cfg.homogeneous_cluster();
    let mut ocfg = OnlineConfig::from_eval(cfg, alpha, windows, windows, false);
    ocfg.events = vec![(fail_window, ClusterEvent::GpuFailed(2))];
    ocfg.coordinator.cooldown_windows = 0;

    let mut report = Report::new(
        &format!(
            "Resilience, stationary Zipf({alpha:.1}): {} experts on {} GPUs, GPU 2 fails at window {fail_window}/{windows}",
            ocfg.n_experts,
            cluster.len()
        ),
        &[
            "total (ms)",
            "p99 window (ms)",
            "post-failure mean (ms)",
            "recovery vs oracle",
            "replans",
        ],
    );

    let outcomes: Vec<_> = [
        OnlineStrategy::Static,
        OnlineStrategy::Coordinator,
        OnlineStrategy::Oracle,
    ]
    .into_iter()
    .map(|strategy| run_online(&ocfg, &cluster, strategy))
    .collect();
    let oracle = &outcomes[2];
    for out in &outcomes {
        let post: Vec<f64> = out.per_window_ms[fail_window..].to_vec();
        let post_mean = post.iter().sum::<f64>() / post.len() as f64;
        // best per-window ratio to the oracle inside the recovery horizon:
        // "how close did this strategy get to a fresh plan, and how fast"
        let horizon = (fail_window + RECOVERY_WINDOWS).min(windows);
        let recovery = (fail_window..horizon)
            .map(|w| out.per_window_ms[w] / oracle.per_window_ms[w])
            .fold(f64::INFINITY, f64::min);
        report.row(
            out.strategy,
            vec![
                out.total_ms,
                out.p99_ms,
                post_mean,
                recovery,
                out.replans as f64,
            ],
        );
    }

    let recovery = report
        .column("recovery vs oracle")
        .expect("column was just added");
    // rows: static, coordinator, oracle
    report.note(format!(
        "coordinator recovers to {:.3}x of the fresh-plan oracle within {RECOVERY_WINDOWS} windows (win condition: <= {RECOVERY_RATIO}x; static stopgap sits at {:.3}x)",
        recovery[1], recovery[0]
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvalConfig {
        EvalConfig {
            n_experts: 4,
            batch_images: 256,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn resilience_figure_pins_the_recovery_win_condition() {
        let cfg = small_cfg();
        let r = resilience_comparison(&cfg, 1.2, 16, 5);
        assert_eq!(r.rows.len(), 3);
        let labels: Vec<&str> = r.rows.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["static", "coordinator", "oracle"]);
        let recovery = r.column("recovery vs oracle").unwrap();
        assert!(
            recovery[1] <= RECOVERY_RATIO,
            "coordinator recovery {} must sit within {RECOVERY_RATIO}x of the oracle",
            recovery[1]
        );
        // the oracle's ratio to itself is exactly 1
        assert!((recovery[2] - 1.0).abs() < 1e-12);
        // the coordinator repaired at least once; static never replans
        let replans = r.column("replans").unwrap();
        assert_eq!(replans[0], 0.0);
        assert!(replans[1] >= 1.0, "{replans:?}");
    }
}
