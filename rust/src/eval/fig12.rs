//! Fig. 12 — GPU utilization in the colocating scenarios.

use super::report::Report;
use super::workloads::Workloads;
use crate::cluster::Cluster;
use crate::config::EvalConfig;
use crate::planner::Planner;
use crate::schedule::SchedulePolicy;
use crate::sim::{simulate_colocated, simulate_exclusive};
use crate::util::mean;

fn utilization_report(cfg: &EvalConfig, w: &Workloads, cluster: &Cluster, title: &str) -> Report {
    let planner = Planner::default();
    let mut r = Report::new(
        title,
        &[
            "aurora+coloc",
            "aurora+excl",
            "lina",
            "coloc/excl",
            "coloc/lina",
        ],
    );
    let _ = cfg;
    for (name, a, b) in w.pairs() {
        // Colocated utilization per layer (plans use precise per-layer stats).
        let coloc: Vec<f64> = (0..a.layers.len())
            .map(|k| {
                let plan = Planner {
                    planning_layer: k,
                    ..planner.clone()
                }
                .plan_colocated(a, b, cluster);
                let ab = plan.assignment_b.clone().unwrap();
                simulate_colocated(
                    &a.layers[k].placed(&plan.assignment_a),
                    &b.layers[k].placed(&ab),
                    cluster,
                    plan.policy,
                )
                .0
                .utilization
            })
            .collect();
        // Exclusive utilization: each model alone on the cluster (mean of the
        // two models, matching the paper's per-deployment bars).
        let excl_plan_a = planner.plan_exclusive(a, cluster);
        let excl_plan_b = planner.plan_exclusive(b, cluster);
        let excl: Vec<f64> = excl_plan_a
            .place_a(a)
            .iter()
            .zip(excl_plan_b.place_a(b).iter())
            .map(|(la, lb)| {
                let ua = simulate_exclusive(la, cluster, SchedulePolicy::Aurora)
                    .0
                    .utilization;
                let ub = simulate_exclusive(lb, cluster, SchedulePolicy::Aurora)
                    .0
                    .utilization;
                (ua + ub) / 2.0
            })
            .collect();
        let lina = super::lina::lina_utilization(a, b, cluster, SchedulePolicy::Rcs { seed: 7 });
        for k in 0..a.layers.len() {
            r.row(
                format!("{name}/L{}", k + 1),
                vec![
                    coloc[k],
                    excl[k],
                    lina[k],
                    coloc[k] / excl[k],
                    coloc[k] / lina[k],
                ],
            );
        }
    }
    let vs_excl = r.column("coloc/excl").expect("column was just added");
    let vs_lina = r.column("coloc/lina").expect("column was just added");
    r.note(format!(
        "utilization gain vs exclusive: {:.2}x mean (paper: 1.57x-1.72x); vs Lina: {:.2}x mean (paper: 1.28x-1.50x)",
        mean(&vs_excl),
        mean(&vs_lina)
    ));
    r
}

/// Fig. 12a — utilization, Colocating + Homogeneous.
pub fn fig12a(cfg: &EvalConfig, w: &Workloads) -> Report {
    utilization_report(
        cfg,
        w,
        &cfg.homogeneous_cluster(),
        "Fig 12a: GPU utilization, Colocating+Homogeneous",
    )
}

/// Fig. 12b — utilization, Colocating + Heterogeneous.
pub fn fig12b(cfg: &EvalConfig, w: &Workloads) -> Report {
    utilization_report(
        cfg,
        w,
        &cfg.heterogeneous_cluster(),
        "Fig 12b: GPU utilization, Colocating+Heterogeneous",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colocation_improves_utilization() {
        let cfg = EvalConfig {
            batch_images: 16,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        for rep in [fig12a(&cfg, &w), fig12b(&cfg, &w)] {
            for v in rep.column("coloc/excl").unwrap() {
                assert!(v > 1.0, "colocation must lift utilization, got {v}");
            }
            for v in rep.column("aurora+coloc").unwrap() {
                assert!(v > 0.0 && v < 1.0);
            }
        }
    }
}
