//! Fig. 11 — inference time across the four scenarios.

use super::report::Report;
use super::workloads::Workloads;
use crate::assignment::random_assignment;
use crate::colocation::hetero::assign_pairs_to_gpus;
use crate::colocation::random_pairing;
use crate::config::EvalConfig;
use crate::planner::{pair_gpu_cost, Planner};
use crate::schedule::SchedulePolicy;
use crate::sim::{simulate_colocated, simulate_exclusive};
use crate::util::{mean, Rng};

/// Expand a pairing `pi` (a-expert → b-expert) and pair assignment `sigma`
/// (a-expert → GPU) into the two per-model assignments.
pub(crate) fn place_pair(pi: &[usize], sigma: &[usize]) -> (Vec<usize>, Vec<usize>) {
    let n = pi.len();
    let mut assignment_b = vec![0usize; n];
    for (i, &j) in pi.iter().enumerate() {
        assignment_b[j] = sigma[i];
    }
    (sigma.to_vec(), assignment_b)
}

/// Fig. 11a — Exclusive + Homogeneous: Aurora vs SJF vs RCS scheduling.
pub fn fig11a(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let mut r = Report::new(
        "Fig 11a: inference time (ms), Exclusive+Homogeneous",
        &["aurora", "sjf", "rcs", "sjf/aurora", "rcs/aurora"],
    );
    let mut max_speedup: f64 = 0.0;
    for (name, trace) in w.singles() {
        for (k, layer) in trace.layers.iter().enumerate() {
            let a = simulate_exclusive(layer, &cluster, SchedulePolicy::Aurora)
                .0
                .inference_ms;
            let s = simulate_exclusive(layer, &cluster, SchedulePolicy::Sjf)
                .0
                .inference_ms;
            let rcs_times: Vec<f64> = (0..cfg.baseline_samples as u64)
                .map(|i| {
                    simulate_exclusive(
                        layer,
                        &cluster,
                        SchedulePolicy::Rcs {
                            seed: cfg.seed.wrapping_add(i),
                        },
                    )
                    .0
                    .inference_ms
                })
                .collect();
            let c = mean(&rcs_times);
            max_speedup = max_speedup.max(s / a).max(c / a);
            r.row(format!("{name}/L{}", k + 1), vec![a, s, c, s / a, c / a]);
        }
    }
    r.note(format!("max speedup vs baselines: {max_speedup:.2}x (paper: up to 1.38x)"));
    r
}

/// Fig. 11b — Exclusive + Heterogeneous: Aurora (Theorem 5.1) vs RGA.
pub fn fig11b(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.heterogeneous_cluster();
    let planner = Planner::default();
    let mut r = Report::new(
        "Fig 11b: inference time (ms), Exclusive+Heterogeneous",
        &["aurora", "rga", "rga/aurora"],
    );
    let mut speedups = Vec::new();
    for (name, trace) in w.singles() {
        let mut rng = Rng::new(cfg.seed ^ 0x11B);
        for k in 0..trace.layers.len() {
            // figs 11-13 assume precise per-layer statistics (imprecision is
            // Fig 14's subject), so the assignment is optimized per layer
            let plan = Planner { planning_layer: k, ..planner.clone() }
                .plan_exclusive_layer(trace, k, &cluster);
            let layer = &trace.layers[k].placed(&plan.assignment_a);
            let a = simulate_exclusive(layer, &cluster, SchedulePolicy::Aurora)
                .0
                .inference_ms;
            let rga_times: Vec<f64> = (0..cfg.baseline_samples)
                .map(|_| {
                    let p = random_assignment(trace.n_experts(), &mut rng);
                    simulate_exclusive(
                        &trace.layers[k].placed(&p),
                        &cluster,
                        SchedulePolicy::Aurora,
                    )
                    .0
                    .inference_ms
                })
                .collect();
            let g = mean(&rga_times);
            speedups.push(g / a);
            r.row(format!("{name}/L{}", k + 1), vec![a, g, g / a]);
        }
    }
    let lo = speedups.iter().cloned().fold(f64::MAX, f64::min);
    let hi = speedups.iter().cloned().fold(f64::MIN, f64::max);
    r.note(format!(
        "speedup vs RGA: {lo:.2}x to {hi:.2}x (paper: 1.36x to 1.81x)"
    ));
    r
}

/// Fig. 11c — Colocating + Homogeneous: Aurora vs Lina vs REC.
pub fn fig11c(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let planner = Planner::default();
    let mut r = Report::new(
        "Fig 11c: inference time (ms), Colocating+Homogeneous",
        &["aurora", "lina(b16)", "lina(b32)", "rec", "lina/aurora", "rec/aurora"],
    );
    let mut speedups = Vec::new();
    for (name, a, b) in w.pairs() {
        // Baselines ship no transmission-order optimization (the paper's
        // comparisons are full-system), so their collectives run RCS.
        let (lina_a, lina_b) =
            super::lina::lina_colocated_times(a, b, &cluster, SchedulePolicy::Rcs { seed: cfg.seed });
        let mut rng = Rng::new(cfg.seed ^ 0x11C);
        let n = a.n_experts();
        let t_aurora: Vec<f64> = (0..a.layers.len())
            .map(|k| {
                let plan = Planner { planning_layer: k, ..planner.clone() }
                    .plan_colocated(a, b, &cluster);
                let ab = plan.assignment_b.clone().unwrap();
                simulate_colocated(
                    &a.layers[k].placed(&plan.assignment_a),
                    &b.layers[k].placed(&ab),
                    &cluster,
                    plan.policy,
                )
                .0
                .inference_ms
            })
            .collect();
        for k in 0..a.layers.len() {
            let rec_times: Vec<f64> = (0..cfg.baseline_samples)
                .map(|_| {
                    let pi = random_pairing(n, &mut rng);
                    let sigma: Vec<usize> = (0..n).collect();
                    let (aa, abb) = place_pair(&pi, &sigma);
                    simulate_colocated(
                        &a.layers[k].placed(&aa),
                        &b.layers[k].placed(&abb),
                        &cluster,
                        SchedulePolicy::Rcs { seed: cfg.seed },
                    )
                    .0
                    .inference_ms
                })
                .collect();
            let rec = mean(&rec_times);
            let lina_worst = lina_a[k].max(lina_b[k]);
            speedups.push(lina_worst / t_aurora[k]);
            r.row(
                format!("{name}/L{}", k + 1),
                vec![
                    t_aurora[k],
                    lina_a[k],
                    lina_b[k],
                    rec,
                    lina_worst / t_aurora[k],
                    rec / t_aurora[k],
                ],
            );
        }
    }
    let lo = speedups.iter().cloned().fold(f64::MAX, f64::min);
    let hi = speedups.iter().cloned().fold(f64::MIN, f64::max);
    r.note(format!(
        "speedup vs Lina: {lo:.2}x to {hi:.2}x (paper: 1.25x to 2.38x)"
    ));
    r
}

/// Fig. 11d — Colocating + Heterogeneous: Aurora vs Lina vs REC vs RGA+REC.
pub fn fig11d(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.heterogeneous_cluster();
    let planner = Planner::default();
    let mut r = Report::new(
        "Fig 11d: inference time (ms), Colocating+Heterogeneous",
        &["aurora", "lina", "rec", "rga+rec", "lina/aurora", "rga+rec/aurora"],
    );
    let mut speedups = Vec::new();
    for (name, a, b) in w.pairs() {
        let t_aurora: Vec<f64> = (0..a.layers.len())
            .map(|k| {
                let plan = Planner { planning_layer: k, ..planner.clone() }
                    .plan_colocated(a, b, &cluster);
                let ab = plan.assignment_b.clone().unwrap();
                simulate_colocated(
                    &a.layers[k].placed(&plan.assignment_a),
                    &b.layers[k].placed(&ab),
                    &cluster,
                    plan.policy,
                )
                .0
                .inference_ms
            })
            .collect();
        // Lina in a mixed cluster: the model halves land on random disjoint
        // GPU subsets (assignment-agnostic baseline); average over samples.
        let mut rng = Rng::new(cfg.seed ^ 0x11D);
        let n = a.n_experts();
        for k in 0..a.layers.len() {
            let mut lina_samples = Vec::new();
            let mut rec_samples = Vec::new();
            let mut rga_rec_samples = Vec::new();
            for _ in 0..cfg.baseline_samples {
                // Lina: random split of GPUs into two halves.
                let split = rng.permutation(n);
                let ra = super::lina::lina_model_results(
                    a,
                    &cluster,
                    &split[..n / 2],
                    SchedulePolicy::Rcs { seed: cfg.seed },
                );
                let rb = super::lina::lina_model_results(
                    b,
                    &cluster,
                    &split[n / 2..],
                    SchedulePolicy::Rcs { seed: cfg.seed },
                );
                lina_samples.push(ra[k].inference_ms.max(rb[k].inference_ms));

                // REC: random pairing, Aurora's stage-2 GPU matching.
                let pi = random_pairing(n, &mut rng);
                let cost = pair_gpu_cost(&a.layers[k], &b.layers[k], &cluster);
                let (_, sigma) = assign_pairs_to_gpus(&pi, n, cost);
                let (aa, abb) = place_pair(&pi, &sigma);
                rec_samples.push(
                    simulate_colocated(
                        &a.layers[k].placed(&aa),
                        &b.layers[k].placed(&abb),
                        &cluster,
                        SchedulePolicy::Rcs { seed: cfg.seed },
                    )
                    .0
                    .inference_ms,
                );

                // RGA+REC: both random.
                let pi2 = random_pairing(n, &mut rng);
                let sigma2 = random_assignment(n, &mut rng);
                let (aa2, abb2) = place_pair(&pi2, &sigma2);
                rga_rec_samples.push(
                    simulate_colocated(
                        &a.layers[k].placed(&aa2),
                        &b.layers[k].placed(&abb2),
                        &cluster,
                        SchedulePolicy::Rcs { seed: cfg.seed },
                    )
                    .0
                    .inference_ms,
                );
            }
            let lina = mean(&lina_samples);
            let rec = mean(&rec_samples);
            let rga_rec = mean(&rga_rec_samples);
            speedups.push(rga_rec / t_aurora[k]);
            r.row(
                format!("{name}/L{}", k + 1),
                vec![
                    t_aurora[k],
                    lina,
                    rec,
                    rga_rec,
                    lina / t_aurora[k],
                    rga_rec / t_aurora[k],
                ],
            );
        }
    }
    let lo = speedups.iter().cloned().fold(f64::MAX, f64::min);
    let hi = speedups.iter().cloned().fold(f64::MIN, f64::max);
    r.note(format!(
        "speedup vs RGA+REC: {lo:.2}x to {hi:.2}x (paper vs baselines: 1.91x to 3.54x)"
    ));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvalConfig {
        EvalConfig {
            baseline_samples: 3,
            batch_images: 16,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn fig11a_aurora_wins_every_row() {
        let cfg = small_cfg();
        let w = Workloads::generate(&cfg);
        let r = fig11a(&cfg, &w);
        assert_eq!(r.rows.len(), 16); // 4 workloads x 4 layers
        for v in r.column("sjf/aurora").unwrap() {
            assert!(v >= 1.0 - 1e-9, "aurora must not lose to sjf: {v}");
        }
        for v in r.column("rcs/aurora").unwrap() {
            assert!(v >= 1.0 - 1e-9);
        }
    }

    #[test]
    fn fig11b_sorted_assignment_wins() {
        let cfg = small_cfg();
        let w = Workloads::generate(&cfg);
        let r = fig11b(&cfg, &w);
        for v in r.column("rga/aurora").unwrap() {
            assert!(v >= 1.0 - 1e-9);
        }
    }

    #[test]
    fn fig11c_aurora_beats_lina_and_rec() {
        let cfg = small_cfg();
        let w = Workloads::generate(&cfg);
        let r = fig11c(&cfg, &w);
        assert_eq!(r.rows.len(), 8); // 2 pairs x 4 layers
        for v in r.column("rec/aurora").unwrap() {
            assert!(v >= 1.0 - 1e-9, "rec/aurora = {v}");
        }
    }

    #[test]
    fn fig11d_aurora_beats_random_baselines() {
        let cfg = small_cfg();
        let w = Workloads::generate(&cfg);
        let r = fig11d(&cfg, &w);
        for v in r.column("rga+rec/aurora").unwrap() {
            assert!(v >= 1.0 - 1e-9, "rga+rec/aurora = {v}");
        }
    }

    #[test]
    fn place_pair_inverts_consistently() {
        let pi = vec![2, 0, 1];
        let sigma = vec![1, 2, 0];
        let (aa, ab) = place_pair(&pi, &sigma);
        assert_eq!(aa, sigma);
        // a-expert 0 on GPU 1, its partner b-expert 2 must be on GPU 1 too
        assert_eq!(ab[2], 1);
        assert_eq!(ab[0], 2);
        assert_eq!(ab[1], 0);
    }
}
