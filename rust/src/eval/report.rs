//! Tabular reports: aligned console output + JSON serialization.

use crate::util::{round_to, Json};
use std::fmt;

/// A requested column the report does not have. Carries the figure title and
/// the column name, so harness callers can *report* the mismatch instead of
/// aborting with a context-free panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MissingColumn {
    /// Title of the figure/report the lookup ran against.
    pub figure: String,
    /// The missing column name.
    pub column: String,
}

impl fmt::Display for MissingColumn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "figure '{}' has no column '{}'",
            self.figure, self.column
        )
    }
}

impl std::error::Error for MissingColumn {}

/// One table of results (≈ one figure panel).
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    /// Figure/panel title.
    pub title: String,
    /// Column headers (not counting the row label).
    pub columns: Vec<String>,
    /// `(label, values)` rows; `values.len() == columns.len()`.
    pub rows: Vec<(String, Vec<f64>)>,
    /// Free-form summary lines (e.g. "speedup up to 2.38x").
    pub notes: Vec<String>,
}

impl Report {
    /// New empty report.
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row (checks arity).
    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row arity mismatch");
        self.rows.push((label.into(), values));
    }

    /// Append a summary note.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Column values across all rows, or a [`MissingColumn`] naming the
    /// figure and the column when the header does not exist.
    pub fn column(&self, name: &str) -> Result<Vec<f64>, MissingColumn> {
        match self.columns.iter().position(|c| c == name) {
            Some(idx) => Ok(self.rows.iter().map(|(_, v)| v[idx]).collect()),
            None => Err(MissingColumn {
                figure: self.title.clone(),
                column: name.to_string(),
            }),
        }
    }

    /// Render an aligned console table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([5])
            .max()
            .unwrap()
            .max(8);
        out.push_str(&format!("{:<label_w$}", ""));
        for c in &self.columns {
            out.push_str(&format!(" {c:>14}"));
        }
        out.push('\n');
        for (label, values) in &self.rows {
            out.push_str(&format!("{label:<label_w$}"));
            for v in values {
                out.push_str(&format!(" {:>14}", format_value(*v)));
            }
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  * {n}\n"));
        }
        out
    }

    /// JSON form (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(label, values)| {
                Json::obj(vec![
                    ("label", Json::from(label.as_str())),
                    (
                        "values",
                        Json::Arr(values.iter().map(|&v| Json::Num(round_to(v, 6))).collect()),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("title", Json::from(self.title.as_str())),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::from(c.as_str())).collect()),
            ),
            ("rows", Json::Arr(rows)),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::from(n.as_str())).collect()),
            ),
        ])
    }
}

fn format_value(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_renders() {
        let mut r = Report::new("Fig X", &["aurora", "sjf"]);
        r.row("layer1", vec![1.0, 1.4]);
        r.row("layer2", vec![2.0, 2.9]);
        r.note("speedup up to 1.45x");
        let s = r.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("layer2"));
        assert!(s.contains("speedup"));
        assert_eq!(r.column("sjf").unwrap(), vec![1.4, 2.9]);
    }

    #[test]
    fn missing_column_names_figure_and_column() {
        let mut r = Report::new("Fig 99", &["a"]);
        r.row("x", vec![1.0]);
        let err = r.column("nope").unwrap_err();
        assert_eq!(err.figure, "Fig 99");
        assert_eq!(err.column, "nope");
        let msg = err.to_string();
        assert!(msg.contains("Fig 99") && msg.contains("nope"), "{msg}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row("x", vec![1.0]);
    }

    #[test]
    fn json_roundtrip_shape() {
        let mut r = Report::new("t", &["a"]);
        r.row("x", vec![0.5]);
        let j = r.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("t"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
