//! Replication extension figure: replicated vs. placed vs. random
//! deployments under Zipf-skewed routing.
//!
//! The paper's evaluation drives uniform-ish LIMoE traces; this driver
//! sweeps the routing skew α of [`crate::traffic::zipf_traffic`] and
//! compares three deployments of one 2×-oversubscribed model (two experts
//! per GPU slot):
//!
//! * **replicated** — [`crate::planner::Planner::plan_replicated`] (base
//!   plan + hot-expert replicas + water-filled token splits);
//! * **placed** — the plain [`crate::planner::Planner::plan_multi`] plan
//!   (the best non-replicated deployment this system produces);
//! * **random** — uniformly random expert→GPU placement (the REC analogue).
//!
//! At α = 0 the replicated plan falls back to the placed plan bit-for-bit,
//! so its column reads 1.00×; as α grows the hot expert pins one GPU and
//! replication is the only lever that keeps the bottleneck bounded.

use super::report::Report;
use crate::config::EvalConfig;
use crate::eval::random_deployment;
use crate::planner::{Planner, ReplicationConfig};
use crate::sim::MoeLayerStats;
use crate::trace::ModelTrace;
use crate::traffic::zipf_traffic;
use crate::util::Rng;

/// Compute-time constants of the skewed workload (the LIMoE reference-GPU
/// profile, see `trace::limoe`).
const GATE_MS: f64 = 0.02;
const FFN_MS_PER_TOKEN: f64 = 0.001;
const AGG_MS: f64 = 0.015;

/// A Zipf(α)-skewed trace: `n_layers` layers of an `n_experts` model, every
/// sender originating `tokens_per_sender` tokens per layer. One seed drives
/// all layers, so the hot expert persists across depth — the regime where a
/// static replication plan pays off.
pub fn skewed_workload(
    n_experts: usize,
    n_layers: usize,
    tokens_per_sender: u64,
    alpha: f64,
    seed: u64,
) -> ModelTrace {
    ModelTrace {
        name: format!("zipf-a{alpha:.1}"),
        layers: (0..n_layers)
            .map(|_| MoeLayerStats {
                traffic: zipf_traffic(n_experts, tokens_per_sender, alpha, seed),
                gate_ms: GATE_MS,
                ffn_ms_per_token: FFN_MS_PER_TOKEN,
                agg_ms: AGG_MS,
            })
            .collect(),
    }
}

/// Replicated vs. placed vs. random total inference time across a skew
/// sweep, on the config's homogeneous cluster with `2 × n_experts` experts
/// packed two per GPU slot.
pub fn replication_comparison(cfg: &EvalConfig, alphas: &[f64]) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let n_experts = cfg.n_experts * 2;
    let tokens_per_sender = cfg.batch_images * 16;
    let planner = Planner::default();
    let rep_cfg = ReplicationConfig::default();

    let mut report = Report::new(
        &format!("Replication under Zipf skew: {n_experts} experts on {} GPUs", cluster.len()),
        &["replicated (ms)", "placed (ms)", "random (ms)", "vs placed", "vs random"],
    );

    for &alpha in alphas {
        let trace = skewed_workload(n_experts, cfg.n_layers, tokens_per_sender, alpha, cfg.seed);
        let refs = [&trace];

        let placed = planner
            .plan_multi(&refs, &cluster)
            .expect("plan_multi succeeds for one model");
        let t_placed = placed.total_inference_ms(&refs, &cluster);

        let (rep, splits) = planner
            .plan_replicated(&refs, &cluster, &rep_cfg)
            .expect("plan_replicated succeeds for one model");
        let t_rep = rep.total_inference_ms(&refs, &cluster, &splits);

        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let mut total = 0.0;
        for _ in 0..cfg.baseline_samples {
            let r = random_deployment(&refs, cluster.len(), placed.scenario, &mut rng);
            total += r.total_inference_ms(&refs, &cluster);
        }
        let t_rand = total / cfg.baseline_samples as f64;

        report.row(
            format!("alpha={alpha:.1}"),
            vec![t_rep, t_placed, t_rand, t_placed / t_rep, t_rand / t_rep],
        );
    }

    let speedups = report
        .column("vs placed")
        .expect("column was just added");
    let max_speedup = speedups.iter().cloned().fold(0.0, f64::max);
    report.note(format!(
        "replication up to {max_speedup:.2}x faster than the best non-replicated plan"
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvalConfig {
        EvalConfig {
            n_layers: 2,
            baseline_samples: 3,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn uniform_row_is_exact_fallback() {
        let r = replication_comparison(&small_cfg(), &[0.0]);
        assert_eq!(r.rows.len(), 1);
        let vals = &r.rows[0].1;
        // replicated == placed bit-for-bit at alpha = 0
        assert!(
            (vals[0] - vals[1]).abs() < 1e-12,
            "replicated {} vs placed {}",
            vals[0],
            vals[1]
        );
        assert!((vals[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skew_sweep_shows_replication_wins() {
        let r = replication_comparison(&small_cfg(), &[0.0, 1.2]);
        assert_eq!(r.rows.len(), 2);
        let speedups = r.column("vs placed").unwrap();
        // monotone: replication can only matter more as skew grows
        assert!(speedups[1] > speedups[0], "{speedups:?}");
        assert!(
            speedups[1] >= 1.2,
            "alpha=1.2 speedup {} below the acceptance bar",
            speedups[1]
        );
        // and the planner never loses to random placement
        for v in r.column("vs random").unwrap() {
            assert!(v >= 0.95, "vs random {v}");
        }
    }
}
