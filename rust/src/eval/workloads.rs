//! The §8.1 workload set: LIMoE B/16 and B/32 on COCO and ImageNet.

use crate::config::EvalConfig;
use crate::trace::{limoe_trace, Dataset, LimoeVariant, ModelTrace};

/// The four model × dataset traces the paper evaluates, plus the colocation
/// pairs (B/16 with B/32 per dataset).
#[derive(Debug, Clone)]
pub struct Workloads {
    /// LIMoE B/16 on COCO.
    pub b16_coco: ModelTrace,
    /// LIMoE B/16 on ImageNet.
    pub b16_imagenet: ModelTrace,
    /// LIMoE B/32 on COCO.
    pub b32_coco: ModelTrace,
    /// LIMoE B/32 on ImageNet.
    pub b32_imagenet: ModelTrace,
}

impl Workloads {
    /// Generate all traces from the config's seed.
    pub fn generate(cfg: &EvalConfig) -> Workloads {
        let t = |variant, dataset, salt: u64| {
            limoe_trace(
                variant,
                dataset,
                cfg.n_experts,
                cfg.n_layers,
                cfg.batch_images,
                cfg.seed.wrapping_add(salt),
            )
        };
        Workloads {
            b16_coco: t(LimoeVariant::B16, Dataset::Coco, 1),
            b16_imagenet: t(LimoeVariant::B16, Dataset::Imagenet, 2),
            b32_coco: t(LimoeVariant::B32, Dataset::Coco, 3),
            b32_imagenet: t(LimoeVariant::B32, Dataset::Imagenet, 4),
        }
    }

    /// All single-model workloads as `(name, trace)`.
    pub fn singles(&self) -> Vec<(&str, &ModelTrace)> {
        vec![
            ("b16-coco", &self.b16_coco),
            ("b16-imagenet", &self.b16_imagenet),
            ("b32-coco", &self.b32_coco),
            ("b32-imagenet", &self.b32_imagenet),
        ]
    }

    /// Colocation pairs `(name, model_a, model_b)`: same variant serving the
    /// two datasets (B/16-coco with B/16-imagenet, B/32-coco with
    /// B/32-imagenet). Equal-sized pairs are the regime in which the paper's
    /// utilization gains (Fig. 12: 1.57x-1.72x) are achievable — colocating a
    /// model with one 4x smaller can at best add 25% compute.
    pub fn pairs(&self) -> Vec<(&str, &ModelTrace, &ModelTrace)> {
        vec![
            ("b16", &self.b16_coco, &self.b16_imagenet),
            ("b32", &self.b32_coco, &self.b32_imagenet),
        ]
    }

    /// The unequal-size pairing (B/16 with B/32) used by ablation benches.
    pub fn pairs_mixed(&self) -> Vec<(&str, &ModelTrace, &ModelTrace)> {
        vec![
            ("coco", &self.b16_coco, &self.b32_coco),
            ("imagenet", &self.b16_imagenet, &self.b32_imagenet),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_paper_workload_set() {
        let w = Workloads::generate(&EvalConfig::default());
        assert_eq!(w.singles().len(), 4);
        assert_eq!(w.pairs().len(), 2);
        for (_, t) in w.singles() {
            assert_eq!(t.layers.len(), 4);
            assert_eq!(t.n_experts(), 8);
        }
    }

    #[test]
    fn traces_differ_across_models() {
        let w = Workloads::generate(&EvalConfig::default());
        assert_ne!(w.b16_coco, w.b16_imagenet);
        assert_ne!(w.b16_coco, w.b32_coco);
    }
}
