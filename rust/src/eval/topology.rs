//! Topology extension figure: hierarchical two-phase scheduling plus
//! topology-aware placement vs flat Aurora vs SJF on a two-tier fabric.
//!
//! The paper's §10 names "varying network topologies" as the open direction;
//! this driver quantifies it on the rack-scale shape the integration suite
//! pins: 16 GPUs in 4 groups serving one Zipf(1.2)-skewed 32-expert model,
//! sweeping the uplink oversubscription factor. Three stacks compete on the
//! planning layer's aggregated GPU traffic:
//!
//! * **hierarchical** — [`crate::planner::Planner::plan_topology`] placement
//!   and the two-phase schedule's pipelined makespan
//!   ([`crate::schedule::comm_time_on`]);
//! * **flat aurora** — topology-blind [`crate::planner::Planner::plan_multi`]
//!   placement with the big-switch Aurora rounds priced honestly on the
//!   uplinks ([`crate::schedule::flat_aurora_on_topology`]);
//! * **sjf** — the same flat placement under shortest-flow-first, floored by
//!   the uplink drain bound.
//!
//! At 1:1 the three largely agree (nothing is oversubscribed); the
//! hierarchical advantage opens as the factor grows.

use super::replication::skewed_workload;
use super::report::Report;
use crate::cluster::{Cluster, Topology};
use crate::config::{gbps_to_tokens_per_ms, EvalConfig};
use crate::planner::Planner;
use crate::schedule::{comm_time_on, flat_aurora_on_topology, SchedulePolicy};
use crate::trace::ModelTrace;

/// GPUs in the rack-scale figure shape.
const N_GPUS: usize = 16;
/// Leaf groups (racks).
const N_GROUPS: usize = 4;
/// Zipf exponent of the skewed routing workload.
const ALPHA: f64 = 1.2;

/// Hierarchical vs flat-Aurora vs SJF all-to-all makespans (planning-layer
/// aggregated traffic, ms) across `oversubs` uplink factors.
pub fn topology_comparison(cfg: &EvalConfig, oversubs: &[f64]) -> Report {
    let bw = gbps_to_tokens_per_ms(cfg.homo_gbps, cfg.token_bytes, cfg.net_efficiency);
    let cluster = Cluster::homogeneous(N_GPUS, bw);
    let trace = skewed_workload(
        N_GPUS * 2,
        cfg.n_layers,
        cfg.batch_images * 16,
        ALPHA,
        cfg.seed,
    );
    let refs: Vec<&ModelTrace> = vec![&trace];
    let planner = Planner::default();
    let flat_dep = planner
        .plan_multi(&refs, &cluster)
        .expect("one model always plans");
    let layer = &trace.layers[0];
    let flat_agg = flat_dep.aggregated_traffic(&[layer]);

    let mut report = Report::new(
        &format!(
            "Two-tier topology: hierarchical vs flat Aurora vs SJF \
             ({N_GPUS} GPUs, {N_GROUPS} groups, Zipf({ALPHA}))"
        ),
        &["hierarchical (ms)", "flat aurora (ms)", "sjf (ms)", "speedup"],
    );
    let mut max_speedup = 0.0f64;
    for &os in oversubs {
        let topo = Topology::even_two_tier(N_GPUS, N_GROUPS, os)
            .expect("figure shape tiles evenly");
        let placed = planner
            .plan_topology(&refs, &cluster, &topo)
            .expect("one model always plans");
        let placed_agg = placed.aggregated_traffic(&[layer]);
        let hier_ms = comm_time_on(&placed_agg, &cluster, &topo, SchedulePolicy::Aurora).makespan;
        let flat_ms = flat_aurora_on_topology(&flat_agg, &cluster, &topo);
        let sjf_ms = comm_time_on(&flat_agg, &cluster, &topo, SchedulePolicy::Sjf).makespan;
        let speedup = flat_ms / hier_ms;
        max_speedup = max_speedup.max(speedup);
        report.row(format!("oversub {os:.0}x"), vec![hier_ms, flat_ms, sjf_ms, speedup]);
    }
    report.note(format!(
        "hierarchical scheduling + placement up to {max_speedup:.2}x faster \
         than flat Aurora under oversubscription"
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_shape_and_monotone_advantage() {
        let cfg = EvalConfig {
            n_layers: 2,
            batch_images: 24,
            ..EvalConfig::default()
        };
        let r = topology_comparison(&cfg, &[1.0, 2.0, 4.0]);
        assert_eq!(r.rows.len(), 3);
        let hier = r.column("hierarchical (ms)").unwrap();
        let flat = r.column("flat aurora (ms)").unwrap();
        let speedup = r.column("speedup").unwrap();
        for (h, f) in hier.iter().zip(&flat) {
            assert!(*h > 0.0 && *f > 0.0);
        }
        // oversubscription can only slow the fixed flat stack down; the
        // hierarchical stack re-places per factor, so allow it slack
        assert!(flat[2] >= flat[0] - 1e-9);
        assert!(hier[2] >= hier[0] * 0.9 - 1e-9);
        // the hierarchical advantage is real at 4x
        assert!(
            speedup[2] > 1.0,
            "expected a hierarchical win at 4x, got {}",
            speedup[2]
        );
        // and grows (weakly) with the factor
        assert!(speedup[2] >= speedup[0] - 1e-9);
    }
}
