//! Utilization-attribution figure (paper §7): exclusive vs colocated vs
//! colocated+Aurora across routing skews, with the idle time *attributed*.
//!
//! The paper's Fig. 2/§7 argument is that exclusive deployments waste GPUs
//! because compute and communication cannot overlap within one model — the
//! engines sit in sync-wait during both all-to-alls — while colocating two
//! models fills those barriers with the other model's compute, and Aurora's
//! communication schedule keeps the shared switch from eroding the gain.
//! This driver reproduces that comparison end to end on the recorded
//! timelines ([`crate::obs::timeline`]): every arm runs through a
//! `*_recorded` simulator, utilizations come from the unchanged
//! [`crate::sim::SimResult`], and the exclusive arm's makespan split
//! (compute / link-busy / sync-wait / idle) comes from
//! [`crate::obs::timeline::Timelines::breakdown`].
//!
//! Workload shape: two independent Zipf(α) models, `n` experts on `n` GPUs
//! one-to-one (the traffic is GPU-indexed as generated, so the placement
//! layer is deliberately out of the loop — the figure isolates colocation
//! and scheduling). The FFN constant is calibrated so per-GPU compute is
//! comparable to one all-to-all (`K ≈ C`), the regime the paper's ≈1.5×
//! utilization claim lives in: colocation cannot help a purely
//! communication-bound layer (nothing to fill the barriers with) nor a
//! purely compute-bound one (no barriers to fill).

use super::report::Report;
use crate::config::EvalConfig;
use crate::obs::timeline::TimelineRecorder;
use crate::schedule::SchedulePolicy;
use crate::sim::{simulate_colocated_recorded, simulate_exclusive_recorded, MoeLayerStats};
use crate::traffic::zipf_traffic;

/// Compute constants of the utilization workload. Gate/aggregation are the
/// LIMoE reference profile; the FFN constant is set so `K/C ≈ 1` at the
/// default 100 Gbps effective bandwidth (≈ 814 tokens/ms): `0.00125 ms/token
/// × 814 tokens/ms ≈ 1.02` — both K and C scale with the hottest expert's
/// column, so the regime holds across the whole skew sweep.
const GATE_MS: f64 = 0.02;
const FFN_MS_PER_TOKEN: f64 = 0.00125;
const AGG_MS: f64 = 0.015;

fn model(n: usize, tokens_per_sender: u64, alpha: f64, seed: u64) -> MoeLayerStats {
    MoeLayerStats {
        traffic: zipf_traffic(n, tokens_per_sender, alpha, seed),
        gate_ms: GATE_MS,
        ffn_ms_per_token: FFN_MS_PER_TOKEN,
        agg_ms: AGG_MS,
    }
}

/// Exclusive vs colocated (RCS) vs colocated+Aurora GPU utilization across
/// a skew sweep, with the exclusive arm's makespan attributed per segment
/// kind from the recorded timeline.
pub fn utilization_figure(cfg: &EvalConfig, alphas: &[f64]) -> Report {
    let cluster = cfg.homogeneous_cluster();
    let n = cluster.len();
    let tokens_per_sender = cfg.batch_images * 16;

    let mut report = Report::new(
        &format!("Utilization attribution: {n} experts on {n} GPUs, two models"),
        &[
            "excl util",
            "coloc util",
            "aurora util",
            "aurora/excl",
            "excl compute%",
            "excl comm%",
            "excl sync%",
            "excl idle%",
        ],
    );

    for &alpha in alphas {
        let a = model(n, tokens_per_sender, alpha, cfg.seed);
        let b = model(n, tokens_per_sender, alpha, cfg.seed + 1);

        // Exclusive: each model alone on its own n GPUs (Aurora collectives
        // — isolation, not scheduling, is this arm's handicap). The arm's
        // utilization is the mean of the two dedicated clusters; the
        // attribution row comes from model A's timeline.
        let mut rec_a = TimelineRecorder::new(n);
        let (res_a, _) =
            simulate_exclusive_recorded(&a, &cluster, SchedulePolicy::Aurora, &mut rec_a);
        let (res_b, _) = simulate_exclusive_recorded(
            &b,
            &cluster,
            SchedulePolicy::Aurora,
            &mut TimelineRecorder::disabled(),
        );
        let excl_util = 0.5 * (res_a.utilization + res_b.utilization);
        let excl = rec_a
            .take()
            .expect("enabled recorder yields timelines")
            .breakdown();

        // Colocated with a randomized baseline schedule (the Lina-style
        // reference point), and colocated under Aurora.
        let (res_rcs, _) = simulate_colocated_recorded(
            &a,
            &b,
            &cluster,
            SchedulePolicy::Rcs { seed: 7 },
            &mut TimelineRecorder::disabled(),
        );
        let (res_aurora, _) = simulate_colocated_recorded(
            &a,
            &b,
            &cluster,
            SchedulePolicy::Aurora,
            &mut TimelineRecorder::disabled(),
        );

        report.row(
            format!("alpha={alpha:.1}"),
            vec![
                excl_util,
                res_rcs.utilization,
                res_aurora.utilization,
                res_aurora.utilization / excl_util,
                100.0 * excl.cluster.compute,
                100.0 * excl.cluster.comm_send,
                100.0 * excl.cluster.sync_wait,
                100.0 * excl.cluster.idle,
            ],
        );
    }

    let ratios = report.column("aurora/excl").expect("column was just added");
    let mean = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    report.note(format!(
        "colocation + Aurora lifts utilization {mean:.2}x over exclusive on average \
         (paper reports ≈1.5x)"
    ));
    report.note(
        "exclusive idle is dominated by sync-wait on the all-to-all barriers, \
         not by trailing idle (see excl sync% vs excl idle%)"
            .to_string(),
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aurora_colocation_clears_the_paper_utilization_bar() {
        let cfg = EvalConfig::default();
        let r = utilization_figure(&cfg, &[0.0, 0.6, 1.2]);
        assert_eq!(r.rows.len(), 3);
        for ratio in r.column("aurora/excl").unwrap() {
            assert!(
                ratio >= 1.3,
                "colocated+Aurora must be >= 1.3x exclusive, got {ratio}"
            );
        }
        // utilizations are sane fractions
        for col in ["excl util", "coloc util", "aurora util"] {
            for v in r.column(col).unwrap() {
                assert!(v > 0.0 && v < 1.0, "{col} = {v}");
            }
        }
    }

    #[test]
    fn exclusive_idle_is_sync_wait_not_trailing() {
        let cfg = EvalConfig::default();
        let r = utilization_figure(&cfg, &[0.6, 1.2]);
        let sync = r.column("excl sync%").unwrap();
        let idle = r.column("excl idle%").unwrap();
        for (s, i) in sync.iter().zip(&idle) {
            assert!(
                s > i,
                "sync-wait ({s}%) must dominate trailing idle ({i}%) in the exclusive arm"
            );
        }
        // engine shares partition the makespan
        let compute = r.column("excl compute%").unwrap();
        for ((c, s), i) in compute.iter().zip(&sync).zip(&idle) {
            assert!(((c + s + i) - 100.0).abs() < 1e-6, "{c} + {s} + {i} != 100");
        }
    }

    #[test]
    fn aurora_never_loses_to_the_rcs_baseline() {
        let cfg = EvalConfig::default();
        let r = utilization_figure(&cfg, &[0.0, 1.2]);
        let rcs = r.column("coloc util").unwrap();
        let aurora = r.column("aurora util").unwrap();
        for (x, y) in rcs.iter().zip(&aurora) {
            assert!(y >= x, "aurora {y} vs rcs {x}");
        }
    }
}
