//! Evaluation harness — regenerates every figure of the paper's §8.
//!
//! One driver per figure; each returns [`Report`]s (printable tables that
//! also serialize to JSON). The mapping between figures, workloads, and
//! modules is indexed in DESIGN.md.
//!
//! | figure | scenario | comparison |
//! |--------|----------|------------|
//! | 11a | Exclusive + Homogeneous | Aurora vs SJF vs RCS (scheduling) |
//! | 11b | Exclusive + Heterogeneous | Aurora vs RGA (assignment) |
//! | 11c | Colocating + Homogeneous | Aurora vs Lina vs REC (colocation) |
//! | 11d | Colocating + Heterogeneous | Aurora vs Lina+RGA vs REC vs RGA+REC |
//! | 12a/b | colocating scenarios | GPU utilization |
//! | 13 | Colocating + Heterogeneous | Aurora vs brute-force optimum |
//! | 14a/b | heterogeneous scenarios | robustness to traffic imprecision |
//! | multi | beyond-paper | generalized M-model placement vs random |
//! | replication | beyond-paper | replicated vs placed vs random under Zipf skew |
//! | online | beyond-paper | drifting routing: static vs periodic vs coordinator vs oracle |
//! | resilience | beyond-paper | mid-trace GPU failure: promote-only vs promote-then-repair vs fresh-plan oracle |
//! | straggler | beyond-paper | gray failure: blind static vs detector-driven coordinator vs oracle-informed plan across severities |
//! | topology | beyond-paper | two-tier fabric: hierarchical vs flat Aurora vs SJF across oversubscription |
//! | utilization | §7 reproduction | exclusive vs colocated vs colocated+Aurora, idle time attributed per segment kind |

mod ablation;
mod fig11;
mod fig12;
mod fig13;
mod fig14;
mod lina;
mod multi;
mod online;
mod replication;
mod report;
mod resilience;
mod straggler;
mod topology;
mod utilization;
mod workloads;

pub use ablation::{ablation_schedulers, ablation_top2};
pub use fig11::{fig11a, fig11b, fig11c, fig11d};
pub use fig12::{fig12a, fig12b};
pub use fig13::fig13;
pub use fig14::{fig14a, fig14b};
pub use lina::{lina_colocated_times, lina_utilization};
pub use multi::{multi_model_comparison, multi_workload, random_deployment};
pub use online::online_comparison;
pub use replication::{replication_comparison, skewed_workload};
pub use report::{MissingColumn, Report};
pub use resilience::resilience_comparison;
pub use straggler::straggler_comparison;
pub use topology::topology_comparison;
pub use utilization::utilization_figure;
pub use workloads::Workloads;

use crate::config::EvalConfig;

/// Run one figure (or `all`) by name; returns the reports in paper order.
pub fn run_figure(name: &str, cfg: &EvalConfig) -> Result<Vec<Report>, String> {
    let w = Workloads::generate(cfg);
    let reports = match name {
        "11a" => vec![fig11a(cfg, &w)],
        "11b" => vec![fig11b(cfg, &w)],
        "11c" => vec![fig11c(cfg, &w)],
        "11d" => vec![fig11d(cfg, &w)],
        "11" => vec![fig11a(cfg, &w), fig11b(cfg, &w), fig11c(cfg, &w), fig11d(cfg, &w)],
        "12" | "12a" | "12b" => match name {
            "12a" => vec![fig12a(cfg, &w)],
            "12b" => vec![fig12b(cfg, &w)],
            _ => vec![fig12a(cfg, &w), fig12b(cfg, &w)],
        },
        "13" => vec![fig13(cfg, &w)],
        "a1" => vec![ablation_schedulers(cfg, &w)],
        "a2" => vec![ablation_top2(cfg, &w)],
        "ablation" => vec![ablation_schedulers(cfg, &w), ablation_top2(cfg, &w)],
        "14" | "14a" | "14b" => match name {
            "14a" => vec![fig14a(cfg, &w)],
            "14b" => vec![fig14b(cfg, &w)],
            _ => vec![fig14a(cfg, &w), fig14b(cfg, &w)],
        },
        // Beyond-paper extension: generalized multi-model placement
        // (3 models, 2x the cluster's expert slots each).
        "multi" => vec![multi_model_comparison(cfg, 3, cfg.n_experts * 2)],
        // Beyond-paper extension: expert replication under Zipf-skewed
        // routing (replicated vs placed vs random across the skew sweep).
        "replication" => vec![replication_comparison(cfg, &[0.0, 0.6, 1.2])],
        // Beyond-paper extension: online serving under drifting routing —
        // static vs periodic vs coordinator vs oracle.
        "online" => vec![online_comparison(cfg, 1.2, 24, 8)],
        // Beyond-paper extension: fault tolerance — a mid-trace GPU failure
        // under a stationary workload: static (promote-only) vs the
        // coordinator's promote-then-repair vs the fresh-plan oracle.
        "resilience" => vec![resilience_comparison(cfg, 1.2, 24, 8)],
        // Beyond-paper extension: gray failures — a mid-trace compute
        // straggler under drifting routing: blind static vs the
        // detector-driven coordinator vs the oracle-informed plan, across
        // degradation severities.
        "straggler" => vec![straggler_comparison(cfg, 1.2, 16, 8, &[0.8, 0.6, 0.4])],
        // Beyond-paper extension: two-tier topologies — hierarchical
        // two-phase scheduling + placement vs flat Aurora vs SJF across
        // uplink oversubscription factors.
        "topology" => vec![topology_comparison(cfg, &[1.0, 2.0, 4.0])],
        // §7 reproduction on the recorded timelines: exclusive vs colocated
        // vs colocated+Aurora utilization with the idle time attributed.
        "utilization" => vec![utilization_figure(cfg, &[0.0, 0.6, 1.2])],
        "all" => {
            let mut r = vec![
                fig11a(cfg, &w),
                fig11b(cfg, &w),
                fig11c(cfg, &w),
                fig11d(cfg, &w),
                fig12a(cfg, &w),
                fig12b(cfg, &w),
            ];
            r.push(fig13(cfg, &w));
            r.push(fig14a(cfg, &w));
            r.push(fig14b(cfg, &w));
            r.push(ablation_schedulers(cfg, &w));
            r.push(ablation_top2(cfg, &w));
            r.push(multi_model_comparison(cfg, 3, cfg.n_experts * 2));
            r.push(replication_comparison(cfg, &[0.0, 0.6, 1.2]));
            r.push(online_comparison(cfg, 1.2, 24, 8));
            r.push(resilience_comparison(cfg, 1.2, 24, 8));
            r.push(straggler_comparison(cfg, 1.2, 16, 8, &[0.8, 0.6, 0.4]));
            r.push(topology_comparison(cfg, &[1.0, 2.0, 4.0]));
            r.push(utilization_figure(cfg, &[0.0, 0.6, 1.2]));
            r
        }
        other => {
            return Err(format!(
                "unknown figure '{other}' (try 11a/11b/11c/11d/12/13/14/a1/a2/ablation/multi/replication/online/resilience/straggler/topology/utilization/all)"
            ))
        }
    };
    Ok(reports)
}
