//! Multi-model extension figure: generalized placement vs random placement.
//!
//! The paper stops at two colocated models; this driver evaluates the
//! generalized planner ([`crate::planner::Planner::plan_multi`]) on M ≥ 2
//! models whose expert counts may exceed the cluster size, against the REC
//! analogue (uniformly random expert→GPU placement), on both cluster kinds.

use super::report::Report;
use crate::config::EvalConfig;
use crate::placement::{Deployment, Scenario};
use crate::planner::Planner;
use crate::trace::{limoe_trace, Dataset, LimoeVariant, ModelTrace};
use crate::util::Rng;

/// Generate `n_models` traces with `n_experts` experts each, cycling the
/// paper's model/dataset grid for variety.
pub fn multi_workload(cfg: &EvalConfig, n_models: usize, n_experts: usize) -> Vec<ModelTrace> {
    let variants = [LimoeVariant::B16, LimoeVariant::B32];
    let datasets = [Dataset::Coco, Dataset::Imagenet];
    (0..n_models)
        .map(|m| {
            limoe_trace(
                variants[m % variants.len()],
                datasets[(m / variants.len()) % datasets.len()],
                n_experts,
                cfg.n_layers,
                cfg.batch_images,
                cfg.seed.wrapping_add(100 + m as u64),
            )
        })
        .collect()
}

/// A uniformly random deployment of the given traces (the REC baseline
/// generalized: every expert lands on an independent uniform GPU).
pub fn random_deployment(
    traces: &[&ModelTrace],
    n_gpus: usize,
    scenario: Scenario,
    rng: &mut Rng,
) -> Deployment {
    let assignments: Vec<Vec<usize>> = traces
        .iter()
        .map(|t| {
            (0..t.n_experts())
                .map(|_| rng.gen_range(n_gpus as u64) as usize)
                .collect()
        })
        .collect();
    Deployment::new(
        n_gpus,
        assignments,
        crate::schedule::SchedulePolicy::Aurora,
        scenario,
    )
    .expect("random assignment is in range")
}

/// Planned vs random placement for `n_models` models of `n_experts` experts
/// each, on the config's homogeneous and heterogeneous clusters. Columns are
/// total simulated inference time (ms, all layers) and the speedup of the
/// plan over the random mean.
pub fn multi_model_comparison(cfg: &EvalConfig, n_models: usize, n_experts: usize) -> Report {
    let traces = multi_workload(cfg, n_models, n_experts);
    let refs: Vec<&ModelTrace> = traces.iter().collect();
    let planner = Planner::default();
    let mut report = Report::new(
        &format!("Multi-model placement: {n_models} models x {n_experts} experts"),
        &["aurora (ms)", "random mean (ms)", "speedup"],
    );

    for (label, cluster) in [
        ("homogeneous", cfg.homogeneous_cluster()),
        ("heterogeneous", cfg.heterogeneous_cluster()),
    ] {
        let dep = planner
            .plan_multi(&refs, &cluster)
            .expect("multi plan succeeds for M >= 1");
        let t_plan = dep.total_inference_ms(&refs, &cluster);

        let scenario = dep.scenario;
        let mut rng = Rng::new(cfg.seed ^ 0x3317);
        let mut total = 0.0;
        for _ in 0..cfg.baseline_samples {
            let r = random_deployment(&refs, cluster.len(), scenario, &mut rng);
            total += r.total_inference_ms(&refs, &cluster);
        }
        let t_rand = total / cfg.baseline_samples as f64;
        report.row(label, vec![t_plan, t_rand, t_rand / t_plan]);
    }
    let speedups = report.column("speedup").expect("column was just added");
    let max_speedup = speedups.iter().cloned().fold(0.0, f64::max);
    report.note(format!(
        "generalized placement up to {max_speedup:.2}x faster than random placement"
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_report_shape_and_wins() {
        let cfg = EvalConfig {
            baseline_samples: 3,
            n_layers: 2,
            batch_images: 24,
            ..EvalConfig::default()
        };
        let r = multi_model_comparison(&cfg, 3, 16);
        assert_eq!(r.rows.len(), 2);
        for (label, vals) in &r.rows {
            assert!(vals[0] > 0.0, "{label}: plan time must be positive");
            assert!(
                vals[0] <= vals[1] * 1.05,
                "{label}: planned {} should not lose to random mean {}",
                vals[0],
                vals[1]
            );
        }
    }

    #[test]
    fn workload_generator_respects_shape() {
        let cfg = EvalConfig::default();
        let w = multi_workload(&cfg, 5, 12);
        assert_eq!(w.len(), 5);
        for t in &w {
            assert_eq!(t.n_experts(), 12);
            assert_eq!(t.layers.len(), cfg.n_layers);
        }
        // distinct seeds -> distinct traces
        assert_ne!(w[0], w[2]);
    }
}
