//! Fig. 14 — robustness to imprecise traffic inputs (Q4).
//!
//! The plan is optimized on layer 1's traffic matrix; the evaluated traffic
//! mixes in the other layers' matrices at 0 / 25 / 50 / 75 % (§8.2): each
//! additional layer of noise raises the imprecision level by 25 points.

use super::fig11::place_pair;
use super::report::Report;
use super::workloads::Workloads;
use crate::assignment::random_assignment;
use crate::colocation::random_pairing;
use crate::config::EvalConfig;
use crate::planner::Planner;
use crate::schedule::SchedulePolicy;
use crate::sim::{simulate_colocated, simulate_exclusive, MoeLayerStats};
use crate::trace::noisy_traffic;
use crate::util::{mean, Rng};

const NOISE_LEVELS: [f64; 4] = [0.0, 0.25, 0.50, 0.75];

fn noisy_layer(trace_layers: &[MoeLayerStats], frac: f64) -> MoeLayerStats {
    let noise: Vec<&crate::traffic::TrafficMatrix> = trace_layers
        .iter()
        .skip(1)
        .map(|l| &l.traffic)
        .collect();
    MoeLayerStats {
        traffic: noisy_traffic(&trace_layers[0].traffic, &noise, frac),
        ..trace_layers[0]
    }
}

/// Fig. 14a — Exclusive + Heterogeneous acceleration vs RGA under noise.
pub fn fig14a(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.heterogeneous_cluster();
    let planner = Planner::default();
    let mut r = Report::new(
        "Fig 14a: acceleration vs RGA under traffic imprecision, Exclusive+Heterogeneous",
        &["0%", "25%", "50%", "75%"],
    );
    let mut degradations = Vec::new();
    for (name, trace) in w.singles() {
        // plan once, on the clean layer-1 statistics
        let plan = planner.plan_exclusive(trace, &cluster);
        let mut rng = Rng::new(cfg.seed ^ 0x14A);
        let mut row = Vec::new();
        for frac in NOISE_LEVELS {
            let actual = noisy_layer(&trace.layers, frac);
            let t_aurora =
                simulate_exclusive(&actual.placed(&plan.assignment_a), &cluster, plan.policy)
                    .0
                    .inference_ms;
            let rga: Vec<f64> = (0..cfg.baseline_samples)
                .map(|_| {
                    let p = random_assignment(trace.n_experts(), &mut rng);
                    simulate_exclusive(&actual.placed(&p), &cluster, SchedulePolicy::Aurora)
                        .0
                        .inference_ms
                })
                .collect();
            row.push(mean(&rga) / t_aurora);
        }
        degradations.push((row[0] - row[3]) / row[0]);
        r.row(name, row);
    }
    r.note(format!(
        "max acceleration loss at 75% noise: {:.1}% (paper: <= 15.8%)",
        degradations.iter().cloned().fold(f64::MIN, f64::max) * 100.0
    ));
    r
}

/// Fig. 14b — Colocating + Heterogeneous acceleration vs RGA+REC under noise.
pub fn fig14b(cfg: &EvalConfig, w: &Workloads) -> Report {
    let cluster = cfg.heterogeneous_cluster();
    let planner = Planner::default();
    let mut r = Report::new(
        "Fig 14b: acceleration vs RGA+REC under traffic imprecision, Colocating+Heterogeneous",
        &["0%", "25%", "50%", "75%"],
    );
    let mut degradations = Vec::new();
    for (name, a, b) in w.pairs() {
        let plan = planner.plan_colocated(a, b, &cluster);
        let ab = plan.assignment_b.clone().unwrap();
        let n = a.n_experts();
        let mut rng = Rng::new(cfg.seed ^ 0x14B);
        let mut row = Vec::new();
        for frac in NOISE_LEVELS {
            let actual_a = noisy_layer(&a.layers, frac);
            let actual_b = noisy_layer(&b.layers, frac);
            let t_aurora = simulate_colocated(
                &actual_a.placed(&plan.assignment_a),
                &actual_b.placed(&ab),
                &cluster,
                plan.policy,
            )
            .0
            .inference_ms;
            let base: Vec<f64> = (0..cfg.baseline_samples)
                .map(|_| {
                    let pi = random_pairing(n, &mut rng);
                    let sigma = random_assignment(n, &mut rng);
                    let (aa, abb) = place_pair(&pi, &sigma);
                    simulate_colocated(
                        &actual_a.placed(&aa),
                        &actual_b.placed(&abb),
                        &cluster,
                        SchedulePolicy::Rcs { seed: cfg.seed },
                    )
                    .0
                    .inference_ms
                })
                .collect();
            row.push(mean(&base) / t_aurora);
        }
        degradations.push((row[0] - row[3]) / row[0]);
        r.row(name, row);
    }
    r.note(format!(
        "max acceleration loss at 75% noise: {:.1}% (paper: <= 15.8%)",
        degradations.iter().cloned().fold(f64::MIN, f64::max) * 100.0
    ));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acceleration_stays_positive_under_noise() {
        let cfg = EvalConfig {
            batch_images: 16,
            baseline_samples: 3,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        for rep in [fig14a(&cfg, &w), fig14b(&cfg, &w)] {
            for (_, values) in &rep.rows {
                // with precise inputs Aurora must win outright
                assert!(values[0] > 1.0, "0% noise: {}", values[0]);
                // under noise (tiny test batches, few baseline samples) it
                // must at least stay competitive; the full-size harness run
                // recorded in EXPERIMENTS.md stays > 1.0 throughout
                for &v in &values[1..] {
                    assert!(v > 0.8, "Aurora collapsed under noise: {v}");
                }
            }
        }
    }

    #[test]
    fn noise_weakens_the_plan_only_mildly() {
        let cfg = EvalConfig {
            batch_images: 32,
            baseline_samples: 5,
            ..EvalConfig::default()
        };
        let w = Workloads::generate(&cfg);
        let r = fig14a(&cfg, &w);
        for (_, values) in &r.rows {
            let degradation = (values[0] - values[3]) / values[0];
            assert!(
                degradation < 0.5,
                "75% noise should not halve the speedup: {degradation}"
            );
        }
    }
}
