//! Straggler extension figure: serving through a gray failure.
//!
//! The workload is the drifting-Zipf(α) stream of the `online` figure; at
//! window `onset` GPU 2 silently drops to a fraction of its nominal compute
//! rate ([`crate::coordinator::ClusterEvent::GpuDegraded`]) — it keeps
//! heartbeating, so membership masks never move and the only way to win is
//! to *notice*. Three strategies serve the identical stream per severity:
//!
//! * **static** — blind and frozen: every window after the onset drags at
//!   the straggler's pace (the cost of not looking);
//! * **detector** — the coordinator with
//!   [`crate::coordinator::online::OnlineConfig::degrade_detection`] on: it
//!   is told nothing and must infer the effective rates from observed
//!   window timelines ([`crate::obs::degrade::DegradationDetector`]), then
//!   replan on the effective cluster (verdicts `degrade_detected` →
//!   `degrade_replanned`);
//! * **oracle** — the oracle-informed baseline: a fresh plan every window
//!   on the *true* effective cluster at zero migration cost. The gap
//!   between detector and oracle is exactly the price of having to detect.
//!
//! The pinned contract (also enforced in `coordinator::online` tests): the
//! detector-driven coordinator recovers to within **1.25×** of the
//! oracle-informed plan within **6 windows** of a 0.4× onset.

use super::report::Report;
use crate::config::EvalConfig;
use crate::coordinator::online::{run_online, OnlineConfig, OnlineStrategy};
use crate::coordinator::ClusterEvent;

/// Windows after the onset within which detector-driven recovery must land.
const RECOVERY_WINDOWS: usize = 6;
/// Recovered latency bound, relative to the oracle-informed plan.
const RECOVERY_RATIO: f64 = 1.25;

/// Serving a drifting-Zipf(`alpha`) workload for `windows` windows with
/// GPU 2 degrading to each of `severities` (× nominal compute) at window
/// `onset`, on the config's homogeneous cluster. Reports total/p99/
/// post-onset latencies and the best post-onset ratio to the
/// oracle-informed plan, per strategy and severity.
pub fn straggler_comparison(
    cfg: &EvalConfig,
    alpha: f64,
    windows: usize,
    onset: usize,
    severities: &[f64],
) -> Report {
    assert!(onset < windows, "the onset must land inside the run");
    assert!(!severities.is_empty(), "sweep at least one severity");
    let cluster = cfg.homogeneous_cluster();
    let base = OnlineConfig::from_eval(cfg, alpha, windows, (windows / 2).max(1), false);

    let mut report = Report::new(
        &format!(
            "Straggler, drifting Zipf({alpha:.1}): {} experts on {} GPUs, GPU 2 degrades at window {onset}/{windows}",
            base.n_experts,
            cluster.len()
        ),
        &[
            "severity",
            "total (ms)",
            "p99 window (ms)",
            "post-onset mean (ms)",
            "recovery vs oracle",
            "replans",
        ],
    );

    let mut detector_recovery_at_04: Option<f64> = None;
    for &severity in severities {
        assert!(
            severity > 0.0 && severity < 1.0,
            "a straggler runs below nominal: severity {severity}"
        );
        let mut ocfg = base.clone();
        ocfg.events = vec![(
            onset,
            ClusterEvent::GpuDegraded {
                gpu: 2,
                compute_scale: severity,
                bandwidth_scale: 1.0,
            },
        )];
        ocfg.coordinator.cooldown_windows = 0;
        ocfg.coordinator.degrade_cooldown_windows = 0;
        let mut detect_cfg = ocfg.clone();
        detect_cfg.degrade_detection = true;

        let stat = run_online(&ocfg, &cluster, OnlineStrategy::Static);
        let det = run_online(&detect_cfg, &cluster, OnlineStrategy::Coordinator);
        let oracle = run_online(&ocfg, &cluster, OnlineStrategy::Oracle);

        let horizon = (onset + RECOVERY_WINDOWS).min(windows);
        for (label, out) in [("static", &stat), ("detector", &det), ("oracle", &oracle)] {
            let post = &out.per_window_ms[onset..];
            let post_mean = post.iter().sum::<f64>() / post.len() as f64;
            // best per-window ratio to the oracle-informed plan inside the
            // recovery horizon: "how close, and how fast"
            let recovery = (onset..horizon)
                .map(|w| out.per_window_ms[w] / oracle.per_window_ms[w])
                .fold(f64::INFINITY, f64::min);
            if label == "detector" && (severity - 0.4).abs() < 1e-9 {
                detector_recovery_at_04 = Some(recovery);
            }
            report.row(
                format!("{label} {severity:.1}x"),
                vec![
                    severity,
                    out.total_ms,
                    out.p99_ms,
                    post_mean,
                    recovery,
                    out.replans as f64,
                ],
            );
        }
    }

    if let Some(recovery) = detector_recovery_at_04 {
        report.note(format!(
            "detector-driven coordinator recovers to {recovery:.3}x of the oracle-informed plan within {RECOVERY_WINDOWS} windows of a 0.4x onset (win condition: <= {RECOVERY_RATIO}x)"
        ));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvalConfig {
        EvalConfig {
            n_experts: 4,
            batch_images: 256,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn straggler_figure_pins_the_detection_recovery_win_condition() {
        let cfg = small_cfg();
        let r = straggler_comparison(&cfg, 1.2, 16, 8, &[0.4]);
        assert_eq!(r.rows.len(), 3);
        let labels: Vec<&str> = r.rows.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["static 0.4x", "detector 0.4x", "oracle 0.4x"]);
        let recovery = r.column("recovery vs oracle").unwrap();
        assert!(
            recovery[1] <= RECOVERY_RATIO,
            "detector recovery {} must sit within {RECOVERY_RATIO}x of the oracle-informed plan",
            recovery[1]
        );
        // the oracle's ratio to itself is exactly 1
        assert!((recovery[2] - 1.0).abs() < 1e-12);
        // the detector replanned at least once; static never replans
        let replans = r.column("replans").unwrap();
        assert_eq!(replans[0], 0.0);
        assert!(replans[1] >= 1.0, "{replans:?}");
        // a milder straggler still hurts the blind plan less than a severe
        // one hurts it; the figure orders rows deterministically
        let again = straggler_comparison(&cfg, 1.2, 16, 8, &[0.4]);
        assert_eq!(r.rows, again.rows);
    }
}
