//! [`GpuSpec`] and [`Cluster`].


/// One GPU's performance envelope.
///
/// `flops_scale` is a relative compute-speed multiplier (1.0 = reference GPU;
/// component times divide by it). `bandwidth` is the full-duplex port speed
/// into the big switch, in **tokens per millisecond** (the config layer
/// converts Gbps + token bytes into this unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Relative compute performance (higher = faster compute).
    pub flops_scale: f64,
    /// Port bandwidth in tokens/ms.
    pub bandwidth: f64,
}

impl GpuSpec {
    /// Reference homogeneous GPU: unit compute, unit bandwidth.
    pub fn reference() -> Self {
        Self {
            flops_scale: 1.0,
            bandwidth: 1.0,
        }
    }

    /// The paper's performance order (§5, footnote 2): compute and bandwidth
    /// are aligned, so a single scalar ranks GPUs. We rank by bandwidth with
    /// flops as tiebreak.
    pub fn perf_key(&self) -> (f64, f64) {
        (self.bandwidth, self.flops_scale)
    }
}

/// A set of GPUs behind one non-blocking big switch.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    gpus: Vec<GpuSpec>,
}

impl Cluster {
    /// Build from explicit GPU specs.
    pub fn new(gpus: Vec<GpuSpec>) -> Self {
        assert!(!gpus.is_empty(), "cluster needs at least one GPU");
        Self { gpus }
    }

    /// `n` identical reference GPUs with the given bandwidth (tokens/ms).
    pub fn homogeneous(n: usize, bandwidth: f64) -> Self {
        Self::new(vec![
            GpuSpec {
                flops_scale: 1.0,
                bandwidth,
            };
            n
        ])
    }

    /// The paper's evaluation cluster (§8.1): four GPU types with bandwidths
    /// 100/80/50/40 Gbps (expressed here as relative token rates 1.0, 0.8,
    /// 0.5, 0.4 × `base_bandwidth`) and matching compute scale, equal counts
    /// per type. `n` must be divisible by 4.
    pub fn paper_heterogeneous(n: usize, base_bandwidth: f64) -> Self {
        assert!(n % 4 == 0, "paper's heterogeneous cluster uses 4 equal-size GPU type groups");
        let fracs = [1.0, 0.8, 0.5, 0.4];
        let mut gpus = Vec::with_capacity(n);
        for f in fracs {
            for _ in 0..n / 4 {
                gpus.push(GpuSpec {
                    flops_scale: f,
                    bandwidth: f * base_bandwidth,
                });
            }
        }
        Self::new(gpus)
    }

    /// Number of GPUs.
    pub fn len(&self) -> usize {
        self.gpus.len()
    }

    /// True if the cluster has no GPUs (never — constructor forbids it).
    pub fn is_empty(&self) -> bool {
        self.gpus.is_empty()
    }

    /// Spec of GPU `i`.
    pub fn gpu(&self, i: usize) -> GpuSpec {
        self.gpus[i]
    }

    /// All specs.
    pub fn gpus(&self) -> &[GpuSpec] {
        &self.gpus
    }

    /// Per-GPU bandwidths (tokens/ms), indexable by GPU id.
    pub fn bandwidths(&self) -> Vec<f64> {
        self.gpus.iter().map(|g| g.bandwidth).collect()
    }

    /// True when every GPU has identical spec.
    pub fn is_homogeneous(&self) -> bool {
        self.gpus.iter().all(|g| *g == self.gpus[0])
    }

    /// GPU ids sorted from highest to lowest performance (Theorem 5.1 order).
    pub fn ids_by_perf_desc(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = (0..self.len()).collect();
        ids.sort_by(|&a, &b| {
            self.gpus[b]
                .perf_key()
                .partial_cmp(&self.gpus[a].perf_key())
                .unwrap()
        });
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_detection() {
        assert!(Cluster::homogeneous(4, 2.0).is_homogeneous());
        assert!(!Cluster::paper_heterogeneous(8, 1.0).is_homogeneous());
    }

    #[test]
    fn paper_cluster_has_four_type_groups() {
        let c = Cluster::paper_heterogeneous(8, 10.0);
        assert_eq!(c.len(), 8);
        let bws = c.bandwidths();
        assert_eq!(bws[0], 10.0);
        assert_eq!(bws[2], 8.0);
        assert_eq!(bws[4], 5.0);
        assert_eq!(bws[6], 4.0);
    }

    #[test]
    #[should_panic]
    fn paper_cluster_rejects_non_multiple_of_four() {
        Cluster::paper_heterogeneous(6, 1.0);
    }

    #[test]
    fn perf_order_descends() {
        let c = Cluster::paper_heterogeneous(8, 1.0);
        let ids = c.ids_by_perf_desc();
        let bws: Vec<f64> = ids.iter().map(|&i| c.gpu(i).bandwidth).collect();
        for w in bws.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    #[should_panic]
    fn empty_cluster_rejected() {
        Cluster::new(vec![]);
    }
}
