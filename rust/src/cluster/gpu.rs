//! [`GpuSpec`], [`Cluster`], and [`GpuScales`] (per-GPU effective-rate
//! multipliers for modeling gray failures: thermal throttling, ECC-retry
//! slowdowns, flaky NICs).


/// One GPU's performance envelope.
///
/// `flops_scale` is a relative compute-speed multiplier (1.0 = reference GPU;
/// component times divide by it). `bandwidth` is the full-duplex port speed
/// into the big switch, in **tokens per millisecond** (the config layer
/// converts Gbps + token bytes into this unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Relative compute performance (higher = faster compute).
    pub flops_scale: f64,
    /// Port bandwidth in tokens/ms.
    pub bandwidth: f64,
}

impl GpuSpec {
    /// Reference homogeneous GPU: unit compute, unit bandwidth.
    pub fn reference() -> Self {
        Self {
            flops_scale: 1.0,
            bandwidth: 1.0,
        }
    }

    /// The paper's performance order (§5, footnote 2): compute and bandwidth
    /// are aligned, so a single scalar ranks GPUs. We rank by bandwidth with
    /// flops as tiebreak.
    pub fn perf_key(&self) -> (f64, f64) {
        (self.bandwidth, self.flops_scale)
    }
}

/// A set of GPUs behind one non-blocking big switch.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    gpus: Vec<GpuSpec>,
}

impl Cluster {
    /// Build from explicit GPU specs.
    pub fn new(gpus: Vec<GpuSpec>) -> Self {
        assert!(!gpus.is_empty(), "cluster needs at least one GPU");
        Self { gpus }
    }

    /// `n` identical reference GPUs with the given bandwidth (tokens/ms).
    pub fn homogeneous(n: usize, bandwidth: f64) -> Self {
        Self::new(vec![
            GpuSpec {
                flops_scale: 1.0,
                bandwidth,
            };
            n
        ])
    }

    /// The paper's evaluation cluster (§8.1): four GPU types with bandwidths
    /// 100/80/50/40 Gbps (expressed here as relative token rates 1.0, 0.8,
    /// 0.5, 0.4 × `base_bandwidth`) and matching compute scale, equal counts
    /// per type. `n` must be divisible by 4.
    pub fn paper_heterogeneous(n: usize, base_bandwidth: f64) -> Self {
        assert!(n % 4 == 0, "paper's heterogeneous cluster uses 4 equal-size GPU type groups");
        let fracs = [1.0, 0.8, 0.5, 0.4];
        let mut gpus = Vec::with_capacity(n);
        for f in fracs {
            for _ in 0..n / 4 {
                gpus.push(GpuSpec {
                    flops_scale: f,
                    bandwidth: f * base_bandwidth,
                });
            }
        }
        Self::new(gpus)
    }

    /// Number of GPUs.
    pub fn len(&self) -> usize {
        self.gpus.len()
    }

    /// True if the cluster has no GPUs (never — constructor forbids it).
    pub fn is_empty(&self) -> bool {
        self.gpus.is_empty()
    }

    /// Spec of GPU `i`.
    pub fn gpu(&self, i: usize) -> GpuSpec {
        self.gpus[i]
    }

    /// All specs.
    pub fn gpus(&self) -> &[GpuSpec] {
        &self.gpus
    }

    /// Per-GPU bandwidths (tokens/ms), indexable by GPU id.
    pub fn bandwidths(&self) -> Vec<f64> {
        self.gpus.iter().map(|g| g.bandwidth).collect()
    }

    /// True when every GPU has identical spec.
    pub fn is_homogeneous(&self) -> bool {
        self.gpus.iter().all(|g| *g == self.gpus[0])
    }

    /// GPU ids sorted from highest to lowest performance (Theorem 5.1 order).
    pub fn ids_by_perf_desc(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = (0..self.len()).collect();
        ids.sort_by(|&a, &b| {
            self.gpus[b]
                .perf_key()
                .partial_cmp(&self.gpus[a].perf_key())
                .unwrap()
        });
        ids
    }
}

/// Per-GPU *effective-rate* multipliers over a nominal [`Cluster`]: a gray
/// failure (thermal throttling, ECC retries, a flaky NIC) degrades a GPU's
/// compute or bandwidth without killing it. `compute[g]` scales GPU `g`'s
/// [`GpuSpec::flops_scale`] and `bandwidth[g]` its port rate; both sit in
/// `(0, 1]`, with 1.0 = nominal. [`GpuScales::scaled`] materializes the
/// effective cluster that planners and simulators price degraded serving on.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuScales {
    /// Per-GPU compute multiplier in `(0, 1]` (1.0 = nominal speed).
    pub compute: Vec<f64>,
    /// Per-GPU port-bandwidth multiplier in `(0, 1]` (1.0 = line rate).
    pub bandwidth: Vec<f64>,
}

impl GpuScales {
    /// All-nominal scales over `n` GPUs.
    pub fn nominal(n: usize) -> GpuScales {
        GpuScales {
            compute: vec![1.0; n],
            bandwidth: vec![1.0; n],
        }
    }

    /// Cluster size the scales cover.
    pub fn n_gpus(&self) -> usize {
        self.compute.len()
    }

    /// True when every multiplier is exactly 1.0 — the fast path where
    /// callers keep the nominal cluster untouched (bit-for-bit behavior).
    pub fn is_nominal(&self) -> bool {
        self.compute.iter().all(|&s| s == 1.0) && self.bandwidth.iter().all(|&s| s == 1.0)
    }

    /// Set GPU `g`'s multipliers (values clamped into `(0, 1]`; a degraded
    /// GPU is slower, never faster).
    pub fn set(&mut self, g: usize, compute: f64, bandwidth: f64) {
        assert!(g < self.n_gpus(), "GPU {g} of {}", self.n_gpus());
        assert!(compute > 0.0 && bandwidth > 0.0, "scales must be positive");
        self.compute[g] = compute.min(1.0);
        self.bandwidth[g] = bandwidth.min(1.0);
    }

    /// Reset GPU `g` to nominal.
    pub fn clear(&mut self, g: usize) {
        self.compute[g] = 1.0;
        self.bandwidth[g] = 1.0;
    }

    /// The effective cluster: every [`GpuSpec`]'s `flops_scale` and
    /// `bandwidth` multiplied by this GPU's scales. Nominal scales return an
    /// identical clone; callers on hot paths should check
    /// [`GpuScales::is_nominal`] first and skip the copy.
    pub fn scaled(&self, cluster: &Cluster) -> Cluster {
        assert_eq!(cluster.len(), self.n_gpus(), "scales must cover the cluster");
        Cluster::new(
            (0..cluster.len())
                .map(|g| {
                    let spec = cluster.gpu(g);
                    GpuSpec {
                        flops_scale: spec.flops_scale * self.compute[g],
                        bandwidth: spec.bandwidth * self.bandwidth[g],
                    }
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_detection() {
        assert!(Cluster::homogeneous(4, 2.0).is_homogeneous());
        assert!(!Cluster::paper_heterogeneous(8, 1.0).is_homogeneous());
    }

    #[test]
    fn paper_cluster_has_four_type_groups() {
        let c = Cluster::paper_heterogeneous(8, 10.0);
        assert_eq!(c.len(), 8);
        let bws = c.bandwidths();
        assert_eq!(bws[0], 10.0);
        assert_eq!(bws[2], 8.0);
        assert_eq!(bws[4], 5.0);
        assert_eq!(bws[6], 4.0);
    }

    #[test]
    #[should_panic]
    fn paper_cluster_rejects_non_multiple_of_four() {
        Cluster::paper_heterogeneous(6, 1.0);
    }

    #[test]
    fn perf_order_descends() {
        let c = Cluster::paper_heterogeneous(8, 1.0);
        let ids = c.ids_by_perf_desc();
        let bws: Vec<f64> = ids.iter().map(|&i| c.gpu(i).bandwidth).collect();
        for w in bws.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    #[should_panic]
    fn empty_cluster_rejected() {
        Cluster::new(vec![]);
    }

    #[test]
    fn nominal_scales_are_identity() {
        let c = Cluster::paper_heterogeneous(8, 10.0);
        let s = GpuScales::nominal(8);
        assert!(s.is_nominal());
        assert_eq!(s.scaled(&c), c);
    }

    #[test]
    fn scaled_cluster_multiplies_compute_and_bandwidth() {
        let c = Cluster::homogeneous(4, 100.0);
        let mut s = GpuScales::nominal(4);
        s.set(2, 0.4, 0.5);
        assert!(!s.is_nominal());
        let eff = s.scaled(&c);
        assert_eq!(eff.gpu(2).flops_scale, 0.4);
        assert_eq!(eff.gpu(2).bandwidth, 50.0);
        for g in [0, 1, 3] {
            assert_eq!(eff.gpu(g), c.gpu(g));
        }
        s.clear(2);
        assert!(s.is_nominal());
        // scales above 1.0 clamp: degradation never speeds a GPU up
        s.set(1, 3.0, 2.0);
        assert_eq!((s.compute[1], s.bandwidth[1]), (1.0, 1.0));
    }
}
