//! Network topologies beyond the big switch — the paper's §10 future-work
//! direction ("extending Aurora to ... varying network topologies").
//!
//! [`Topology::TwoTier`] models the common rack-scale reality: GPUs sit in
//! groups (racks / leaf switches) with full-rate ports inside the group, but
//! the group's uplink into the spine is **oversubscribed** — its capacity is
//! `Σ member port rates / oversubscription`.
//!
//! The Theorem 4.2 lower bound generalizes cleanly: a collective can finish
//! no earlier than the slowest of (a) any GPU's port drain time and (b) any
//! group uplink's drain time in either direction. Aurora's contention-free
//! ordering still achieves the port part; the uplink part needs a schedule
//! that *coordinates* uplink usage — that is
//! [`crate::schedule::hierarchical_schedule`], the two-phase decomposition
//! that runs Aurora within each group at port rate and slot-schedules the
//! residual cross-group traffic on the uplinks via a group-level BvN
//! decomposition. [`comm_time_topology`] keeps the fluid-bound view for
//! ordered baselines: `max(flat simulated makespan, uplink bound)`.
//!
//! Construction is validated: [`Topology::two_tier`] and
//! [`Topology::even_two_tier`] return a typed [`TopologyError`] (consistent
//! with [`crate::placement::Scenario::detect`]) instead of panicking on
//! overlapping, non-covering, or empty groups.

use super::Cluster;
use crate::schedule::{comm_time, CommResult, SchedulePolicy};
use crate::traffic::TrafficMatrix;
use std::fmt;

/// Why a two-tier topology description is invalid.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologyError {
    /// A two-tier topology needs at least one group.
    NoGroups,
    /// A group has no member GPUs.
    EmptyGroup {
        /// Offending group index.
        group: usize,
    },
    /// A GPU appears in more than one group (or twice in one group).
    OverlappingGroups {
        /// The GPU listed more than once.
        gpu: usize,
    },
    /// A group lists a GPU the cluster does not have.
    GpuOutOfRange {
        /// The out-of-range GPU id.
        gpu: usize,
        /// Group that listed it.
        group: usize,
        /// Cluster size.
        n_gpus: usize,
    },
    /// A cluster GPU belongs to no group (the grouping must cover).
    UncoveredGpu {
        /// The unassigned GPU.
        gpu: usize,
    },
    /// Oversubscription must be a finite factor ≥ 1.
    BadOversubscription {
        /// The rejected value.
        value: f64,
    },
    /// `even_two_tier` needs the group count to divide the GPU count.
    UnevenGroups {
        /// Cluster size.
        n_gpus: usize,
        /// Requested group count.
        n_groups: usize,
    },
    /// A non-leaf tier of a [`Topology::Tiered`] fabric lists a child unit
    /// the level below does not have.
    UnitOutOfRange {
        /// The out-of-range lower-level group id.
        unit: usize,
        /// The level whose group listed it (1 = groups of leaf groups).
        level: usize,
        /// Group within that level.
        group: usize,
        /// How many units the level below actually has.
        n_units: usize,
    },
    /// A unit of a lower tier belongs to no group of the tier above (every
    /// aggregation level must cover the level below).
    UncoveredUnit {
        /// The unassigned lower-level group id.
        unit: usize,
        /// The level that fails to cover it.
        level: usize,
    },
}

impl fmt::Display for TopologyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyError::NoGroups => write!(f, "two-tier topology needs at least one group"),
            TopologyError::EmptyGroup { group } => write!(f, "group {group} has no member GPUs"),
            TopologyError::OverlappingGroups { gpu } => {
                write!(f, "GPU {gpu} appears in more than one group")
            }
            TopologyError::GpuOutOfRange { gpu, group, n_gpus } => write!(
                f,
                "group {group} lists GPU {gpu}, but the cluster has {n_gpus}"
            ),
            TopologyError::UncoveredGpu { gpu } => {
                write!(f, "GPU {gpu} belongs to no group (grouping must cover the cluster)")
            }
            TopologyError::BadOversubscription { value } => {
                write!(f, "oversubscription must be a finite factor >= 1, got {value}")
            }
            TopologyError::UnevenGroups { n_gpus, n_groups } => write!(
                f,
                "{n_groups} equal groups cannot tile {n_gpus} GPUs (count must divide evenly)"
            ),
            TopologyError::UnitOutOfRange {
                unit,
                level,
                group,
                n_units,
            } => write!(
                f,
                "level {level} group {group} lists unit {unit}, but the level below has {n_units}"
            ),
            TopologyError::UncoveredUnit { unit, level } => write!(
                f,
                "unit {unit} belongs to no level-{level} group (each tier must cover the one below)"
            ),
        }
    }
}

impl std::error::Error for TopologyError {}

/// Inter-GPU network topology.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Non-blocking big switch (§2.4) — the paper's base model.
    BigSwitch,
    /// Two-tier leaf/spine: `groups[g]` lists member GPU ids;
    /// `oversubscription ≥ 1` divides each group's aggregate uplink rate.
    /// Build via [`Topology::two_tier`] / [`Topology::even_two_tier`] so the
    /// invariants (disjoint, non-empty groups; sane factor) are checked.
    TwoTier {
        /// Disjoint GPU groups covering the cluster.
        groups: Vec<Vec<usize>>,
        /// Uplink oversubscription factor (1.0 = non-blocking).
        oversubscription: f64,
    },
    /// Recursive multi-tier fabric (pod / leaf / spine and deeper):
    /// `levels[0]` partitions GPUs into leaf groups (racks), `levels[1]`
    /// partitions those leaf groups into pods, and so on — each level's
    /// uplinks oversubscribed by its own factor. Build via
    /// [`Topology::tiered`] / [`Topology::even_tiered`] so the per-level
    /// invariants (disjoint non-empty groups, full coverage of the level
    /// below, sane factors) are checked.
    Tiered {
        /// Aggregation levels, innermost first.
        levels: Vec<TierLevel>,
    },
}

/// One aggregation level of a [`Topology::Tiered`] fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct TierLevel {
    /// Disjoint groups of the units one level down: GPU ids at level 0,
    /// level-`t-1` group ids at level `t`.
    pub groups: Vec<Vec<usize>>,
    /// Uplink oversubscription factor at this level (1.0 = non-blocking).
    pub oversubscription: f64,
}

impl Topology {
    /// Validated two-tier topology from explicit groups. Coverage is checked
    /// against a cluster size later ([`Topology::owners`]); everything
    /// cluster-independent — empty group lists, duplicate members, a bad
    /// factor — is rejected here.
    pub fn two_tier(
        groups: Vec<Vec<usize>>,
        oversubscription: f64,
    ) -> Result<Topology, TopologyError> {
        if groups.is_empty() {
            return Err(TopologyError::NoGroups);
        }
        for (g, members) in groups.iter().enumerate() {
            if members.is_empty() {
                return Err(TopologyError::EmptyGroup { group: g });
            }
        }
        let mut seen = std::collections::BTreeSet::new();
        for members in &groups {
            for &i in members {
                if !seen.insert(i) {
                    return Err(TopologyError::OverlappingGroups { gpu: i });
                }
            }
        }
        if !(oversubscription >= 1.0 && oversubscription.is_finite()) {
            return Err(TopologyError::BadOversubscription {
                value: oversubscription,
            });
        }
        Ok(Topology::TwoTier {
            groups,
            oversubscription,
        })
    }

    /// Two-tier topology with `n_groups` equal contiguous groups.
    pub fn even_two_tier(
        n_gpus: usize,
        n_groups: usize,
        oversubscription: f64,
    ) -> Result<Topology, TopologyError> {
        if n_groups == 0 {
            return Err(TopologyError::NoGroups);
        }
        if n_gpus == 0 || n_gpus % n_groups != 0 {
            return Err(TopologyError::UnevenGroups { n_gpus, n_groups });
        }
        let per = n_gpus / n_groups;
        Topology::two_tier(
            (0..n_groups)
                .map(|g| (g * per..(g + 1) * per).collect())
                .collect(),
            oversubscription,
        )
    }

    /// Validated recursive tiered topology. Level 0's coverage of the GPUs
    /// is checked against a cluster size later ([`Topology::owners`]), like
    /// [`Topology::two_tier`]; every aggregation level above it has a known
    /// unit count, so its coverage is checked here.
    pub fn tiered(levels: Vec<TierLevel>) -> Result<Topology, TopologyError> {
        if levels.is_empty() {
            return Err(TopologyError::NoGroups);
        }
        for (t, level) in levels.iter().enumerate() {
            if level.groups.is_empty() {
                return Err(TopologyError::NoGroups);
            }
            for (g, members) in level.groups.iter().enumerate() {
                if members.is_empty() {
                    return Err(TopologyError::EmptyGroup { group: g });
                }
            }
            if !(level.oversubscription >= 1.0 && level.oversubscription.is_finite()) {
                return Err(TopologyError::BadOversubscription {
                    value: level.oversubscription,
                });
            }
            let mut seen = std::collections::BTreeSet::new();
            for members in &level.groups {
                for &u in members {
                    if !seen.insert(u) {
                        return Err(TopologyError::OverlappingGroups { gpu: u });
                    }
                }
            }
            if t > 0 {
                let n_units = levels[t - 1].groups.len();
                for (g, members) in level.groups.iter().enumerate() {
                    for &u in members {
                        if u >= n_units {
                            return Err(TopologyError::UnitOutOfRange {
                                unit: u,
                                level: t,
                                group: g,
                                n_units,
                            });
                        }
                    }
                }
                for u in 0..n_units {
                    if !seen.contains(&u) {
                        return Err(TopologyError::UncoveredUnit { unit: u, level: t });
                    }
                }
            }
        }
        Ok(Topology::Tiered { levels })
    }

    /// Evenly-tiered topology: `group_counts[0]` contiguous leaf groups of
    /// GPUs, `group_counts[t]` contiguous groups of the level below, each
    /// count dividing the unit count it partitions. A 1024-GPU pod fabric of
    /// 16 pods × 8 racks × 8 GPUs is `even_tiered(1024, &[128, 16], ...)`.
    pub fn even_tiered(
        n_gpus: usize,
        group_counts: &[usize],
        oversubscriptions: &[f64],
    ) -> Result<Topology, TopologyError> {
        if group_counts.is_empty() || group_counts.len() != oversubscriptions.len() {
            return Err(TopologyError::NoGroups);
        }
        let mut levels = Vec::with_capacity(group_counts.len());
        let mut units = n_gpus;
        for (&count, &os) in group_counts.iter().zip(oversubscriptions) {
            if count == 0 {
                return Err(TopologyError::NoGroups);
            }
            if units == 0 || units % count != 0 {
                return Err(TopologyError::UnevenGroups {
                    n_gpus: units,
                    n_groups: count,
                });
            }
            let per = units / count;
            levels.push(TierLevel {
                groups: (0..count)
                    .map(|g| (g * per..(g + 1) * per).collect())
                    .collect(),
                oversubscription: os,
            });
            units = count;
        }
        Topology::tiered(levels)
    }

    /// Number of groups (1 for the big switch — one non-blocking domain).
    /// For tiered fabrics this is the innermost (leaf) group count.
    pub fn n_groups(&self) -> usize {
        match self {
            Topology::BigSwitch => 1,
            Topology::TwoTier { groups, .. } => groups.len(),
            Topology::Tiered { levels } => levels[0].groups.len(),
        }
    }

    /// Number of aggregation levels: 0 for the big switch, 1 for two-tier,
    /// `levels.len()` for a tiered fabric.
    pub fn n_levels(&self) -> usize {
        match self {
            Topology::BigSwitch => 0,
            Topology::TwoTier { .. } => 1,
            Topology::Tiered { levels } => levels.len(),
        }
    }

    /// Group id of each GPU, validated against the cluster size: `None` for
    /// the big switch, an error when the grouping overlaps, exceeds the
    /// cluster, or fails to cover it.
    pub fn owners(&self, n_gpus: usize) -> Result<Option<Vec<usize>>, TopologyError> {
        match self {
            Topology::BigSwitch => Ok(None),
            Topology::TwoTier { groups, .. } => leaf_owners_of(groups, n_gpus).map(Some),
            Topology::Tiered { levels } => leaf_owners_of(&levels[0].groups, n_gpus).map(Some),
        }
    }

    /// Level-`level` group id of each GPU — the leaf grouping at level 0,
    /// composed through the parent tiers above it. Panics when
    /// `level >= n_levels()` (the big switch has no levels).
    pub fn owners_at(&self, n_gpus: usize, level: usize) -> Result<Vec<usize>, TopologyError> {
        assert!(
            level < self.n_levels(),
            "level {level} out of range for a {}-level topology",
            self.n_levels()
        );
        match self {
            Topology::BigSwitch => unreachable!("big switch has no aggregation levels"),
            Topology::TwoTier { groups, .. } => leaf_owners_of(groups, n_gpus),
            Topology::Tiered { levels } => {
                let mut owner = leaf_owners_of(&levels[0].groups, n_gpus)?;
                for t in 1..=level {
                    // validated at construction: every unit below has exactly
                    // one parent group at this level
                    let n_units = levels[t - 1].groups.len();
                    let mut parent = vec![usize::MAX; n_units];
                    for (g, members) in levels[t].groups.iter().enumerate() {
                        for &u in members {
                            parent[u] = g;
                        }
                    }
                    for o in owner.iter_mut() {
                        *o = parent[*o];
                    }
                }
                Ok(owner)
            }
        }
    }

    /// Group id of each GPU (`None` for the big switch). Panics on an
    /// invalid grouping — use [`Topology::owners`] for the checked form;
    /// topologies built via [`Topology::two_tier`] and matched to the right
    /// cluster size never panic here.
    pub fn group_of(&self, n_gpus: usize) -> Option<Vec<usize>> {
        self.owners(n_gpus).expect("invalid two-tier topology")
    }

    /// Per-group uplink rates (tokens/ms): member port sum over the
    /// oversubscription factor. Empty for the big switch; the innermost
    /// (leaf) level for tiered fabrics.
    pub fn uplink_rates(&self, cluster: &Cluster) -> Vec<f64> {
        match self {
            Topology::BigSwitch => vec![],
            Topology::TwoTier {
                groups,
                oversubscription,
            } => groups
                .iter()
                .map(|members| {
                    members.iter().map(|&i| cluster.gpu(i).bandwidth).sum::<f64>()
                        / oversubscription
                })
                .collect(),
            Topology::Tiered { .. } => self.uplink_rates_at(cluster, 0),
        }
    }

    /// Uplink rates of the level-`level` groups: the transitive member port
    /// sum over that level's oversubscription factor. Panics when
    /// `level >= n_levels()`.
    pub fn uplink_rates_at(&self, cluster: &Cluster, level: usize) -> Vec<f64> {
        assert!(
            level < self.n_levels(),
            "level {level} out of range for a {}-level topology",
            self.n_levels()
        );
        match self {
            Topology::BigSwitch => unreachable!("big switch has no aggregation levels"),
            Topology::TwoTier { .. } => self.uplink_rates(cluster),
            Topology::Tiered { levels } => {
                // cascade raw port-bandwidth sums up the hierarchy, then
                // apply the requested level's oversubscription
                let mut sums: Vec<f64> = levels[0]
                    .groups
                    .iter()
                    .map(|members| members.iter().map(|&i| cluster.gpu(i).bandwidth).sum())
                    .collect();
                for lv in &levels[1..=level] {
                    sums = lv
                        .groups
                        .iter()
                        .map(|members| members.iter().map(|&u| sums[u]).sum())
                        .collect();
                }
                let os = levels[level].oversubscription;
                sums.iter().map(|s| s / os).collect()
            }
        }
    }
}

/// GPU -> group map for one grouping level, validated against the cluster
/// size (shared by the two-tier and tiered leaf levels).
fn leaf_owners_of(groups: &[Vec<usize>], n_gpus: usize) -> Result<Vec<usize>, TopologyError> {
    let mut owner = vec![usize::MAX; n_gpus];
    for (g, members) in groups.iter().enumerate() {
        for &i in members {
            if i >= n_gpus {
                return Err(TopologyError::GpuOutOfRange {
                    gpu: i,
                    group: g,
                    n_gpus,
                });
            }
            if owner[i] != usize::MAX {
                return Err(TopologyError::OverlappingGroups { gpu: i });
            }
            owner[i] = g;
        }
    }
    if let Some(gpu) = owner.iter().position(|&o| o == usize::MAX) {
        return Err(TopologyError::UncoveredGpu { gpu });
    }
    Ok(owner)
}

/// Drain-time lower bound imposed by group uplinks: for each group at every
/// aggregation level, the time to push all its outbound inter-group tokens
/// up (and pull inbound ones down) through the oversubscribed uplink. Zero
/// for the big switch; the single leaf level for two-tier; the max across
/// all levels for tiered fabrics. Walks the nonzero structure only, so a
/// sparse matrix pays for its traffic, not for `n²`.
pub fn uplink_bound(d: &TrafficMatrix, cluster: &Cluster, topo: &Topology) -> f64 {
    let n = d.n();
    let mut bound = 0.0f64;
    for level in 0..topo.n_levels() {
        let owner = topo.owners_at(n, level).expect("invalid topology");
        let rates = topo.uplink_rates_at(cluster, level);
        let mut up_tokens = vec![0u64; rates.len()];
        let mut down_tokens = vec![0u64; rates.len()];
        for i in 0..n {
            for (j, v) in d.row_iter(i) {
                if i != j && owner[i] != owner[j] {
                    up_tokens[owner[i]] += v;
                    down_tokens[owner[j]] += v;
                }
            }
        }
        for (g, &uplink_rate) in rates.iter().enumerate() {
            bound = bound
                .max(up_tokens[g] as f64 / uplink_rate)
                .max(down_tokens[g] as f64 / uplink_rate);
        }
    }
    bound
}

/// Communication time under `topo` for **ordered baselines** (and the big
/// switch): the flat big-switch result combined with the uplink drain bound.
/// The fluid argument: a baseline order is what it is regardless of the
/// topology, so transfers crossing a saturated uplink serialize there and
/// the makespan cannot beat either bound. Aurora on a two-tier topology
/// should instead be priced through the two-phase hierarchical schedule
/// ([`crate::schedule::comm_time_on`]), which coordinates uplink usage.
pub fn comm_time_topology(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
) -> CommResult {
    let flat = comm_time(d, &cluster.bandwidths(), policy);
    let uplink = uplink_bound(d, cluster, topo);
    CommResult {
        makespan: flat.makespan.max(uplink),
        per_gpu_finish: flat
            .per_gpu_finish
            .iter()
            .map(|&t| t.max(uplink))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_matrix(n: usize, seed: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(30));
                }
            }
        }
        d
    }

    #[test]
    fn big_switch_has_no_uplink_bound() {
        let d = rand_matrix(8, 1);
        let c = Cluster::homogeneous(8, 1.0);
        assert_eq!(uplink_bound(&d, &c, &Topology::BigSwitch), 0.0);
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora);
        let topo = comm_time_topology(&d, &c, &Topology::BigSwitch, SchedulePolicy::Aurora);
        assert_eq!(flat.makespan, topo.makespan);
    }

    #[test]
    fn non_oversubscribed_two_tier_can_match_big_switch() {
        // with oversubscription 1.0 the uplink rarely binds (aggregate rate
        // equals member port sum)
        let d = rand_matrix(8, 2);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 1.0).unwrap();
        let t = comm_time_topology(&d, &c, &topo, SchedulePolicy::Aurora);
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora);
        // uplink bound <= flat b_max when no oversubscription and groups of 4
        assert!(t.makespan <= flat.makespan * 1.5);
    }

    #[test]
    fn oversubscription_monotonically_slows_collectives() {
        let d = rand_matrix(8, 3);
        let c = Cluster::homogeneous(8, 1.0);
        let mut last = 0.0;
        for os in [1.0, 2.0, 4.0, 8.0] {
            let topo = Topology::even_two_tier(8, 2, os).unwrap();
            let t = comm_time_topology(&d, &c, &topo, SchedulePolicy::Aurora).makespan;
            assert!(t >= last, "os={os}");
            last = t;
        }
        // at 8:1 the uplink must dominate
        let t8 = comm_time_topology(
            &d,
            &c,
            &Topology::even_two_tier(8, 2, 8.0).unwrap(),
            SchedulePolicy::Aurora,
        )
        .makespan;
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora).makespan;
        assert!(t8 > flat);
    }

    #[test]
    fn intra_group_traffic_escapes_the_uplink() {
        // all traffic inside group 0: the uplink bound is zero
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 1, 100);
        d.set(1, 2, 100);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
    }

    #[test]
    fn colocating_pairing_can_localize_traffic() {
        // a pairing that keeps chatty experts in one rack avoids the uplink:
        // the bound depends on the placement permutation
        let mut d = TrafficMatrix::zeros(4);
        d.set(0, 1, 100);
        d.set(1, 0, 100);
        let c = Cluster::homogeneous(4, 1.0);
        let topo = Topology::even_two_tier(4, 2, 4.0).unwrap();
        // experts 0,1 in the same rack: no uplink traffic
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
        // split them across racks: heavy uplink traffic
        let split = d.permute(&[0, 2, 1, 3]);
        assert!(uplink_bound(&split, &c, &topo) > 0.0);
    }

    #[test]
    fn overlapping_groups_rejected() {
        // across groups
        assert_eq!(
            Topology::two_tier(vec![vec![0, 1], vec![1, 2]], 2.0),
            Err(TopologyError::OverlappingGroups { gpu: 1 })
        );
        // within one group
        assert_eq!(
            Topology::two_tier(vec![vec![0, 0], vec![1, 2]], 2.0),
            Err(TopologyError::OverlappingGroups { gpu: 0 })
        );
    }

    #[test]
    fn empty_and_missing_groups_rejected() {
        assert_eq!(Topology::two_tier(vec![], 2.0), Err(TopologyError::NoGroups));
        assert_eq!(
            Topology::two_tier(vec![vec![0], vec![]], 2.0),
            Err(TopologyError::EmptyGroup { group: 1 })
        );
        assert_eq!(
            Topology::even_two_tier(8, 0, 2.0),
            Err(TopologyError::NoGroups)
        );
    }

    #[test]
    fn non_covering_and_out_of_range_groupings_rejected() {
        // valid construction, but checked against the wrong cluster size
        let topo = Topology::two_tier(vec![vec![0, 1], vec![2, 3]], 2.0).unwrap();
        assert_eq!(
            topo.owners(3),
            Err(TopologyError::GpuOutOfRange {
                gpu: 3,
                group: 1,
                n_gpus: 3
            })
        );
        // a 5-GPU cluster leaves GPU 4 uncovered
        assert_eq!(topo.owners(5), Err(TopologyError::UncoveredGpu { gpu: 4 }));
        // the matching size is fine
        assert_eq!(topo.owners(4).unwrap(), Some(vec![0, 0, 1, 1]));
    }

    #[test]
    fn bad_oversubscription_rejected() {
        for bad in [0.5, 0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = Topology::two_tier(vec![vec![0]], bad).unwrap_err();
            assert!(
                matches!(err, TopologyError::BadOversubscription { .. }),
                "{bad}: {err}"
            );
            assert!(err.to_string().contains(">= 1"), "{err}");
        }
    }

    #[test]
    fn uneven_tiling_rejected() {
        assert_eq!(
            Topology::even_two_tier(10, 4, 2.0),
            Err(TopologyError::UnevenGroups {
                n_gpus: 10,
                n_groups: 4
            })
        );
        assert_eq!(
            Topology::even_two_tier(0, 2, 2.0),
            Err(TopologyError::UnevenGroups {
                n_gpus: 0,
                n_groups: 2
            })
        );
    }

    #[test]
    fn single_level_tiered_matches_two_tier() {
        // one aggregation level: Tiered must price exactly like TwoTier
        let d = rand_matrix(8, 9);
        let c = Cluster::homogeneous(8, 1.0);
        let two = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let one = Topology::even_tiered(8, &[2], &[4.0]).unwrap();
        assert_eq!(one.n_levels(), 1);
        assert_eq!(one.n_groups(), 2);
        assert_eq!(one.owners(8).unwrap(), two.owners(8).unwrap());
        assert_eq!(one.uplink_rates(&c), two.uplink_rates(&c));
        assert_eq!(uplink_bound(&d, &c, &one), uplink_bound(&d, &c, &two));
    }

    #[test]
    fn tiered_owners_compose_through_levels() {
        // 8 GPUs, 4 racks of 2, 2 pods of 2 racks
        let topo = Topology::even_tiered(8, &[4, 2], &[2.0, 4.0]).unwrap();
        assert_eq!(topo.n_levels(), 2);
        assert_eq!(topo.owners_at(8, 0).unwrap(), vec![0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(topo.owners_at(8, 1).unwrap(), vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }

    #[test]
    fn tiered_uplink_rates_cascade() {
        let c = Cluster::homogeneous(8, 2.0);
        let topo = Topology::even_tiered(8, &[4, 2], &[2.0, 4.0]).unwrap();
        // leaf: 2 members x 2.0 over 2x = 2.0; pod: 4 GPUs x 2.0 over 4x = 2.0
        assert_eq!(topo.uplink_rates_at(&c, 0), vec![2.0; 4]);
        assert_eq!(topo.uplink_rates_at(&c, 1), vec![2.0; 2]);
        assert_eq!(topo.uplink_rates(&c), vec![2.0; 4]);
    }

    #[test]
    fn tiered_uplink_bound_takes_the_binding_level() {
        // cross-pod traffic only: the pod level binds harder than the leaf
        // level once its oversubscription dominates
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 4, 80); // pod 0 -> pod 1
        let c = Cluster::homogeneous(8, 1.0);
        let mild = Topology::even_tiered(8, &[4, 2], &[2.0, 1.0]).unwrap();
        let harsh = Topology::even_tiered(8, &[4, 2], &[2.0, 8.0]).unwrap();
        // leaf bound: 80 / (2*1.0/2) = 80; pod bound at 8x: 80 / (4/8) = 160
        assert_eq!(uplink_bound(&d, &c, &mild), 80.0);
        assert_eq!(uplink_bound(&d, &c, &harsh), 160.0);
    }

    #[test]
    fn intra_leaf_traffic_escapes_every_tier() {
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 1, 500);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_tiered(8, &[4, 2], &[4.0, 8.0]).unwrap();
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
    }

    #[test]
    fn tiered_construction_rejects_bad_shapes() {
        // empty levels
        assert_eq!(Topology::tiered(vec![]), Err(TopologyError::NoGroups));
        // parent lists a missing child unit
        let err = Topology::tiered(vec![
            TierLevel {
                groups: vec![vec![0, 1], vec![2, 3]],
                oversubscription: 2.0,
            },
            TierLevel {
                groups: vec![vec![0, 7]],
                oversubscription: 2.0,
            },
        ])
        .unwrap_err();
        assert!(matches!(err, TopologyError::UnitOutOfRange { unit: 7, .. }), "{err}");
        // parent fails to cover a child unit
        let err = Topology::tiered(vec![
            TierLevel {
                groups: vec![vec![0, 1], vec![2, 3]],
                oversubscription: 2.0,
            },
            TierLevel {
                groups: vec![vec![0]],
                oversubscription: 2.0,
            },
        ])
        .unwrap_err();
        assert!(matches!(err, TopologyError::UncoveredUnit { unit: 1, level: 1 }), "{err}");
        // uneven tiling
        assert!(matches!(
            Topology::even_tiered(10, &[4], &[2.0]),
            Err(TopologyError::UnevenGroups { .. })
        ));
        // mismatched factor list
        assert_eq!(
            Topology::even_tiered(8, &[4, 2], &[2.0]),
            Err(TopologyError::NoGroups)
        );
        // bad oversubscription at a parent level
        assert!(matches!(
            Topology::even_tiered(8, &[4, 2], &[2.0, 0.5]),
            Err(TopologyError::BadOversubscription { .. })
        ));
    }

    #[test]
    fn uplink_rates_follow_member_bandwidth() {
        let c = Cluster::homogeneous(8, 2.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        // 4 members x 2.0 tokens/ms over a 4x factor = 2.0 per uplink
        assert_eq!(topo.uplink_rates(&c), vec![2.0, 2.0]);
        assert!(Topology::BigSwitch.uplink_rates(&c).is_empty());
        assert_eq!(Topology::BigSwitch.n_groups(), 1);
        assert_eq!(topo.n_groups(), 2);
    }
}
