//! Network topologies beyond the big switch — the paper's §10 future-work
//! direction ("extending Aurora to ... varying network topologies").
//!
//! [`Topology::TwoTier`] models the common rack-scale reality: GPUs sit in
//! groups (racks / leaf switches) with full-rate ports inside the group, but
//! the group's uplink into the spine is **oversubscribed** — its capacity is
//! `Σ member port rates / oversubscription`.
//!
//! The Theorem 4.2 lower bound generalizes cleanly: a collective can finish
//! no earlier than the slowest of (a) any GPU's port drain time and (b) any
//! group uplink's drain time in either direction. Aurora's contention-free
//! ordering still achieves the port part; the uplink part needs a schedule
//! that *coordinates* uplink usage — that is
//! [`crate::schedule::hierarchical_schedule`], the two-phase decomposition
//! that runs Aurora within each group at port rate and slot-schedules the
//! residual cross-group traffic on the uplinks via a group-level BvN
//! decomposition. [`comm_time_topology`] keeps the fluid-bound view for
//! ordered baselines: `max(flat simulated makespan, uplink bound)`.
//!
//! Construction is validated: [`Topology::two_tier`] and
//! [`Topology::even_two_tier`] return a typed [`TopologyError`] (consistent
//! with [`crate::placement::Scenario::detect`]) instead of panicking on
//! overlapping, non-covering, or empty groups.

use super::Cluster;
use crate::schedule::{comm_time, CommResult, SchedulePolicy};
use crate::traffic::TrafficMatrix;
use std::fmt;

/// Why a two-tier topology description is invalid.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologyError {
    /// A two-tier topology needs at least one group.
    NoGroups,
    /// A group has no member GPUs.
    EmptyGroup {
        /// Offending group index.
        group: usize,
    },
    /// A GPU appears in more than one group (or twice in one group).
    OverlappingGroups {
        /// The GPU listed more than once.
        gpu: usize,
    },
    /// A group lists a GPU the cluster does not have.
    GpuOutOfRange {
        /// The out-of-range GPU id.
        gpu: usize,
        /// Group that listed it.
        group: usize,
        /// Cluster size.
        n_gpus: usize,
    },
    /// A cluster GPU belongs to no group (the grouping must cover).
    UncoveredGpu {
        /// The unassigned GPU.
        gpu: usize,
    },
    /// Oversubscription must be a finite factor ≥ 1.
    BadOversubscription {
        /// The rejected value.
        value: f64,
    },
    /// `even_two_tier` needs the group count to divide the GPU count.
    UnevenGroups {
        /// Cluster size.
        n_gpus: usize,
        /// Requested group count.
        n_groups: usize,
    },
}

impl fmt::Display for TopologyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyError::NoGroups => write!(f, "two-tier topology needs at least one group"),
            TopologyError::EmptyGroup { group } => write!(f, "group {group} has no member GPUs"),
            TopologyError::OverlappingGroups { gpu } => {
                write!(f, "GPU {gpu} appears in more than one group")
            }
            TopologyError::GpuOutOfRange { gpu, group, n_gpus } => write!(
                f,
                "group {group} lists GPU {gpu}, but the cluster has {n_gpus}"
            ),
            TopologyError::UncoveredGpu { gpu } => {
                write!(f, "GPU {gpu} belongs to no group (grouping must cover the cluster)")
            }
            TopologyError::BadOversubscription { value } => {
                write!(f, "oversubscription must be a finite factor >= 1, got {value}")
            }
            TopologyError::UnevenGroups { n_gpus, n_groups } => write!(
                f,
                "{n_groups} equal groups cannot tile {n_gpus} GPUs (count must divide evenly)"
            ),
        }
    }
}

impl std::error::Error for TopologyError {}

/// Inter-GPU network topology.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Non-blocking big switch (§2.4) — the paper's base model.
    BigSwitch,
    /// Two-tier leaf/spine: `groups[g]` lists member GPU ids;
    /// `oversubscription ≥ 1` divides each group's aggregate uplink rate.
    /// Build via [`Topology::two_tier`] / [`Topology::even_two_tier`] so the
    /// invariants (disjoint, non-empty groups; sane factor) are checked.
    TwoTier {
        /// Disjoint GPU groups covering the cluster.
        groups: Vec<Vec<usize>>,
        /// Uplink oversubscription factor (1.0 = non-blocking).
        oversubscription: f64,
    },
}

impl Topology {
    /// Validated two-tier topology from explicit groups. Coverage is checked
    /// against a cluster size later ([`Topology::owners`]); everything
    /// cluster-independent — empty group lists, duplicate members, a bad
    /// factor — is rejected here.
    pub fn two_tier(
        groups: Vec<Vec<usize>>,
        oversubscription: f64,
    ) -> Result<Topology, TopologyError> {
        if groups.is_empty() {
            return Err(TopologyError::NoGroups);
        }
        for (g, members) in groups.iter().enumerate() {
            if members.is_empty() {
                return Err(TopologyError::EmptyGroup { group: g });
            }
        }
        let mut seen = std::collections::BTreeSet::new();
        for members in &groups {
            for &i in members {
                if !seen.insert(i) {
                    return Err(TopologyError::OverlappingGroups { gpu: i });
                }
            }
        }
        if !(oversubscription >= 1.0 && oversubscription.is_finite()) {
            return Err(TopologyError::BadOversubscription {
                value: oversubscription,
            });
        }
        Ok(Topology::TwoTier {
            groups,
            oversubscription,
        })
    }

    /// Two-tier topology with `n_groups` equal contiguous groups.
    pub fn even_two_tier(
        n_gpus: usize,
        n_groups: usize,
        oversubscription: f64,
    ) -> Result<Topology, TopologyError> {
        if n_groups == 0 {
            return Err(TopologyError::NoGroups);
        }
        if n_gpus == 0 || n_gpus % n_groups != 0 {
            return Err(TopologyError::UnevenGroups { n_gpus, n_groups });
        }
        let per = n_gpus / n_groups;
        Topology::two_tier(
            (0..n_groups)
                .map(|g| (g * per..(g + 1) * per).collect())
                .collect(),
            oversubscription,
        )
    }

    /// Number of groups (1 for the big switch — one non-blocking domain).
    pub fn n_groups(&self) -> usize {
        match self {
            Topology::BigSwitch => 1,
            Topology::TwoTier { groups, .. } => groups.len(),
        }
    }

    /// Group id of each GPU, validated against the cluster size: `None` for
    /// the big switch, an error when the grouping overlaps, exceeds the
    /// cluster, or fails to cover it.
    pub fn owners(&self, n_gpus: usize) -> Result<Option<Vec<usize>>, TopologyError> {
        match self {
            Topology::BigSwitch => Ok(None),
            Topology::TwoTier { groups, .. } => {
                let mut owner = vec![usize::MAX; n_gpus];
                for (g, members) in groups.iter().enumerate() {
                    for &i in members {
                        if i >= n_gpus {
                            return Err(TopologyError::GpuOutOfRange {
                                gpu: i,
                                group: g,
                                n_gpus,
                            });
                        }
                        if owner[i] != usize::MAX {
                            return Err(TopologyError::OverlappingGroups { gpu: i });
                        }
                        owner[i] = g;
                    }
                }
                if let Some(gpu) = owner.iter().position(|&o| o == usize::MAX) {
                    return Err(TopologyError::UncoveredGpu { gpu });
                }
                Ok(Some(owner))
            }
        }
    }

    /// Group id of each GPU (`None` for the big switch). Panics on an
    /// invalid grouping — use [`Topology::owners`] for the checked form;
    /// topologies built via [`Topology::two_tier`] and matched to the right
    /// cluster size never panic here.
    pub fn group_of(&self, n_gpus: usize) -> Option<Vec<usize>> {
        self.owners(n_gpus).expect("invalid two-tier topology")
    }

    /// Per-group uplink rates (tokens/ms): member port sum over the
    /// oversubscription factor. Empty for the big switch.
    pub fn uplink_rates(&self, cluster: &Cluster) -> Vec<f64> {
        match self {
            Topology::BigSwitch => vec![],
            Topology::TwoTier {
                groups,
                oversubscription,
            } => groups
                .iter()
                .map(|members| {
                    members.iter().map(|&i| cluster.gpu(i).bandwidth).sum::<f64>()
                        / oversubscription
                })
                .collect(),
        }
    }
}

/// Drain-time lower bound imposed by group uplinks: for each group, the time
/// to push all its outbound inter-group tokens up (and pull inbound ones
/// down) through the oversubscribed uplink.
pub fn uplink_bound(d: &TrafficMatrix, cluster: &Cluster, topo: &Topology) -> f64 {
    let n = d.n();
    let Some(owner) = topo.group_of(n) else {
        return 0.0;
    };
    let rates = topo.uplink_rates(cluster);
    let mut bound = 0.0f64;
    for (g, &uplink_rate) in rates.iter().enumerate() {
        let mut up_tokens = 0u64;
        let mut down_tokens = 0u64;
        for i in 0..n {
            for j in 0..n {
                if i == j || owner[i] != g && owner[j] != g {
                    continue;
                }
                if owner[i] == g && owner[j] != g {
                    up_tokens += d.get(i, j);
                } else if owner[i] != g && owner[j] == g {
                    down_tokens += d.get(i, j);
                }
            }
        }
        bound = bound
            .max(up_tokens as f64 / uplink_rate)
            .max(down_tokens as f64 / uplink_rate);
    }
    bound
}

/// Communication time under `topo` for **ordered baselines** (and the big
/// switch): the flat big-switch result combined with the uplink drain bound.
/// The fluid argument: a baseline order is what it is regardless of the
/// topology, so transfers crossing a saturated uplink serialize there and
/// the makespan cannot beat either bound. Aurora on a two-tier topology
/// should instead be priced through the two-phase hierarchical schedule
/// ([`crate::schedule::comm_time_on`]), which coordinates uplink usage.
pub fn comm_time_topology(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
) -> CommResult {
    let flat = comm_time(d, &cluster.bandwidths(), policy);
    let uplink = uplink_bound(d, cluster, topo);
    CommResult {
        makespan: flat.makespan.max(uplink),
        per_gpu_finish: flat
            .per_gpu_finish
            .iter()
            .map(|&t| t.max(uplink))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_matrix(n: usize, seed: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(30));
                }
            }
        }
        d
    }

    #[test]
    fn big_switch_has_no_uplink_bound() {
        let d = rand_matrix(8, 1);
        let c = Cluster::homogeneous(8, 1.0);
        assert_eq!(uplink_bound(&d, &c, &Topology::BigSwitch), 0.0);
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora);
        let topo = comm_time_topology(&d, &c, &Topology::BigSwitch, SchedulePolicy::Aurora);
        assert_eq!(flat.makespan, topo.makespan);
    }

    #[test]
    fn non_oversubscribed_two_tier_can_match_big_switch() {
        // with oversubscription 1.0 the uplink rarely binds (aggregate rate
        // equals member port sum)
        let d = rand_matrix(8, 2);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 1.0).unwrap();
        let t = comm_time_topology(&d, &c, &topo, SchedulePolicy::Aurora);
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora);
        // uplink bound <= flat b_max when no oversubscription and groups of 4
        assert!(t.makespan <= flat.makespan * 1.5);
    }

    #[test]
    fn oversubscription_monotonically_slows_collectives() {
        let d = rand_matrix(8, 3);
        let c = Cluster::homogeneous(8, 1.0);
        let mut last = 0.0;
        for os in [1.0, 2.0, 4.0, 8.0] {
            let topo = Topology::even_two_tier(8, 2, os).unwrap();
            let t = comm_time_topology(&d, &c, &topo, SchedulePolicy::Aurora).makespan;
            assert!(t >= last, "os={os}");
            last = t;
        }
        // at 8:1 the uplink must dominate
        let t8 = comm_time_topology(
            &d,
            &c,
            &Topology::even_two_tier(8, 2, 8.0).unwrap(),
            SchedulePolicy::Aurora,
        )
        .makespan;
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora).makespan;
        assert!(t8 > flat);
    }

    #[test]
    fn intra_group_traffic_escapes_the_uplink() {
        // all traffic inside group 0: the uplink bound is zero
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 1, 100);
        d.set(1, 2, 100);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
    }

    #[test]
    fn colocating_pairing_can_localize_traffic() {
        // a pairing that keeps chatty experts in one rack avoids the uplink:
        // the bound depends on the placement permutation
        let mut d = TrafficMatrix::zeros(4);
        d.set(0, 1, 100);
        d.set(1, 0, 100);
        let c = Cluster::homogeneous(4, 1.0);
        let topo = Topology::even_two_tier(4, 2, 4.0).unwrap();
        // experts 0,1 in the same rack: no uplink traffic
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
        // split them across racks: heavy uplink traffic
        let split = d.permute(&[0, 2, 1, 3]);
        assert!(uplink_bound(&split, &c, &topo) > 0.0);
    }

    #[test]
    fn overlapping_groups_rejected() {
        // across groups
        assert_eq!(
            Topology::two_tier(vec![vec![0, 1], vec![1, 2]], 2.0),
            Err(TopologyError::OverlappingGroups { gpu: 1 })
        );
        // within one group
        assert_eq!(
            Topology::two_tier(vec![vec![0, 0], vec![1, 2]], 2.0),
            Err(TopologyError::OverlappingGroups { gpu: 0 })
        );
    }

    #[test]
    fn empty_and_missing_groups_rejected() {
        assert_eq!(Topology::two_tier(vec![], 2.0), Err(TopologyError::NoGroups));
        assert_eq!(
            Topology::two_tier(vec![vec![0], vec![]], 2.0),
            Err(TopologyError::EmptyGroup { group: 1 })
        );
        assert_eq!(
            Topology::even_two_tier(8, 0, 2.0),
            Err(TopologyError::NoGroups)
        );
    }

    #[test]
    fn non_covering_and_out_of_range_groupings_rejected() {
        // valid construction, but checked against the wrong cluster size
        let topo = Topology::two_tier(vec![vec![0, 1], vec![2, 3]], 2.0).unwrap();
        assert_eq!(
            topo.owners(3),
            Err(TopologyError::GpuOutOfRange {
                gpu: 3,
                group: 1,
                n_gpus: 3
            })
        );
        // a 5-GPU cluster leaves GPU 4 uncovered
        assert_eq!(topo.owners(5), Err(TopologyError::UncoveredGpu { gpu: 4 }));
        // the matching size is fine
        assert_eq!(topo.owners(4).unwrap(), Some(vec![0, 0, 1, 1]));
    }

    #[test]
    fn bad_oversubscription_rejected() {
        for bad in [0.5, 0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = Topology::two_tier(vec![vec![0]], bad).unwrap_err();
            assert!(
                matches!(err, TopologyError::BadOversubscription { .. }),
                "{bad}: {err}"
            );
            assert!(err.to_string().contains(">= 1"), "{err}");
        }
    }

    #[test]
    fn uneven_tiling_rejected() {
        assert_eq!(
            Topology::even_two_tier(10, 4, 2.0),
            Err(TopologyError::UnevenGroups {
                n_gpus: 10,
                n_groups: 4
            })
        );
        assert_eq!(
            Topology::even_two_tier(0, 2, 2.0),
            Err(TopologyError::UnevenGroups {
                n_gpus: 0,
                n_groups: 2
            })
        );
    }

    #[test]
    fn uplink_rates_follow_member_bandwidth() {
        let c = Cluster::homogeneous(8, 2.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        // 4 members x 2.0 tokens/ms over a 4x factor = 2.0 per uplink
        assert_eq!(topo.uplink_rates(&c), vec![2.0, 2.0]);
        assert!(Topology::BigSwitch.uplink_rates(&c).is_empty());
        assert_eq!(Topology::BigSwitch.n_groups(), 1);
        assert_eq!(topo.n_groups(), 2);
    }
}
