//! Network topologies beyond the big switch — the paper's §10 future-work
//! direction ("extending Aurora to ... varying network topologies").
//!
//! [`Topology::TwoTier`] models the common rack-scale reality: GPUs sit in
//! groups (racks / leaf switches) with full-rate ports inside the group, but
//! the group's uplink into the spine is **oversubscribed** — its capacity is
//! `Σ member port rates / oversubscription`.
//!
//! The Theorem 4.2 lower bound generalizes cleanly: a collective can finish
//! no earlier than the slowest of (a) any GPU's port drain time and (b) any
//! group uplink's drain time in either direction. Aurora's contention-free
//! ordering still achieves the port part; the uplink part is a fluid bound
//! the schedule inherits (transfers crossing a saturated uplink are what
//! they are regardless of order), so we report
//! `max(port bound, uplink bound)` for Aurora and
//! `max(flat simulated makespan, uplink bound)` for ordered baselines.

use super::Cluster;
use crate::schedule::{comm_time, CommResult, SchedulePolicy};
use crate::traffic::TrafficMatrix;

/// Inter-GPU network topology.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Non-blocking big switch (§2.4) — the paper's base model.
    BigSwitch,
    /// Two-tier leaf/spine: `groups[g]` lists member GPU ids;
    /// `oversubscription ≥ 1` divides each group's aggregate uplink rate.
    TwoTier {
        /// Disjoint GPU groups covering the cluster.
        groups: Vec<Vec<usize>>,
        /// Uplink oversubscription factor (1.0 = non-blocking).
        oversubscription: f64,
    },
}

impl Topology {
    /// Two-tier topology with `n_groups` equal contiguous groups.
    pub fn even_two_tier(n_gpus: usize, n_groups: usize, oversubscription: f64) -> Topology {
        assert!(n_groups > 0 && n_gpus % n_groups == 0);
        assert!(oversubscription >= 1.0);
        let per = n_gpus / n_groups;
        Topology::TwoTier {
            groups: (0..n_groups)
                .map(|g| (g * per..(g + 1) * per).collect())
                .collect(),
            oversubscription,
        }
    }

    /// Group id of each GPU (`None` for the big switch).
    pub fn group_of(&self, n_gpus: usize) -> Option<Vec<usize>> {
        match self {
            Topology::BigSwitch => None,
            Topology::TwoTier { groups, .. } => {
                let mut owner = vec![usize::MAX; n_gpus];
                for (g, members) in groups.iter().enumerate() {
                    for &i in members {
                        assert!(i < n_gpus && owner[i] == usize::MAX, "bad grouping");
                        owner[i] = g;
                    }
                }
                assert!(owner.iter().all(|&o| o != usize::MAX), "grouping must cover");
                Some(owner)
            }
        }
    }
}

/// Drain-time lower bound imposed by group uplinks: for each group, the time
/// to push all its outbound inter-group tokens up (and pull inbound ones
/// down) through the oversubscribed uplink.
pub fn uplink_bound(d: &TrafficMatrix, cluster: &Cluster, topo: &Topology) -> f64 {
    let n = d.n();
    let Some(owner) = topo.group_of(n) else {
        return 0.0;
    };
    let Topology::TwoTier {
        groups,
        oversubscription,
    } = topo
    else {
        return 0.0;
    };
    let mut bound = 0.0f64;
    for (g, members) in groups.iter().enumerate() {
        let uplink_rate: f64 =
            members.iter().map(|&i| cluster.gpu(i).bandwidth).sum::<f64>() / oversubscription;
        let mut up_tokens = 0u64;
        let mut down_tokens = 0u64;
        for i in 0..n {
            for j in 0..n {
                if i == j || owner[i] != g && owner[j] != g {
                    continue;
                }
                if owner[i] == g && owner[j] != g {
                    up_tokens += d.get(i, j);
                } else if owner[i] != g && owner[j] == g {
                    down_tokens += d.get(i, j);
                }
            }
        }
        bound = bound
            .max(up_tokens as f64 / uplink_rate)
            .max(down_tokens as f64 / uplink_rate);
    }
    bound
}

/// Communication time under `topo`: the flat big-switch result combined with
/// the uplink drain bound (see module docs for the modelling argument).
pub fn comm_time_topology(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
) -> CommResult {
    let flat = comm_time(d, &cluster.bandwidths(), policy);
    let uplink = uplink_bound(d, cluster, topo);
    CommResult {
        makespan: flat.makespan.max(uplink),
        per_gpu_finish: flat
            .per_gpu_finish
            .iter()
            .map(|&t| t.max(uplink))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_matrix(n: usize, seed: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(30));
                }
            }
        }
        d
    }

    #[test]
    fn big_switch_has_no_uplink_bound() {
        let d = rand_matrix(8, 1);
        let c = Cluster::homogeneous(8, 1.0);
        assert_eq!(uplink_bound(&d, &c, &Topology::BigSwitch), 0.0);
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora);
        let topo = comm_time_topology(&d, &c, &Topology::BigSwitch, SchedulePolicy::Aurora);
        assert_eq!(flat.makespan, topo.makespan);
    }

    #[test]
    fn non_oversubscribed_two_tier_can_match_big_switch() {
        // with oversubscription 1.0 the uplink rarely binds (aggregate rate
        // equals member port sum)
        let d = rand_matrix(8, 2);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 1.0);
        let t = comm_time_topology(&d, &c, &topo, SchedulePolicy::Aurora);
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora);
        // uplink bound <= flat b_max when no oversubscription and groups of 4
        assert!(t.makespan <= flat.makespan * 1.5);
    }

    #[test]
    fn oversubscription_monotonically_slows_collectives() {
        let d = rand_matrix(8, 3);
        let c = Cluster::homogeneous(8, 1.0);
        let mut last = 0.0;
        for os in [1.0, 2.0, 4.0, 8.0] {
            let topo = Topology::even_two_tier(8, 2, os);
            let t = comm_time_topology(&d, &c, &topo, SchedulePolicy::Aurora).makespan;
            assert!(t >= last, "os={os}");
            last = t;
        }
        // at 8:1 the uplink must dominate
        let t8 = comm_time_topology(
            &d,
            &c,
            &Topology::even_two_tier(8, 2, 8.0),
            SchedulePolicy::Aurora,
        )
        .makespan;
        let flat = comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora).makespan;
        assert!(t8 > flat);
    }

    #[test]
    fn intra_group_traffic_escapes_the_uplink() {
        // all traffic inside group 0: the uplink bound is zero
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 1, 100);
        d.set(1, 2, 100);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0);
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
    }

    #[test]
    fn colocating_pairing_can_localize_traffic() {
        // a pairing that keeps chatty experts in one rack avoids the uplink:
        // the bound depends on the placement permutation
        let mut d = TrafficMatrix::zeros(4);
        d.set(0, 1, 100);
        d.set(1, 0, 100);
        let c = Cluster::homogeneous(4, 1.0);
        let topo = Topology::even_two_tier(4, 2, 4.0);
        // experts 0,1 in the same rack: no uplink traffic
        assert_eq!(uplink_bound(&d, &c, &topo), 0.0);
        // split them across racks: heavy uplink traffic
        let split = d.permute(&[0, 2, 1, 3]);
        assert!(uplink_bound(&split, &c, &topo) > 0.0);
    }

    #[test]
    #[should_panic]
    fn overlapping_groups_rejected() {
        let topo = Topology::TwoTier {
            groups: vec![vec![0, 1], vec![1, 2]],
            oversubscription: 2.0,
        };
        topo.group_of(3);
    }
}
