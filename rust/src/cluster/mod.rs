//! GPU and cluster models.
//!
//! The paper models the inter-GPU fabric as a non-blocking *big switch*
//! (§2.4, Fig. 4a): every GPU has one full-duplex port into the switch; the
//! only contention points are the per-GPU tx/rx ports. Heterogeneous clusters
//! (§5, §7) mix GPU types that differ in compute performance and port
//! bandwidth, with the paper's standing assumption (footnote 2) that a GPU
//! with higher compute never has lower bandwidth.

mod gpu;
pub mod topology;

pub use gpu::{Cluster, GpuScales, GpuSpec};
pub use topology::{comm_time_topology, uplink_bound, TierLevel, Topology, TopologyError};
