//! Structured decision logs — the "why" pillar of the observability layer.
//!
//! A [`DecisionRecord`] is a timestamped, typed key→value record of one
//! decision a subsystem made: the coordinator's replan gate emits one per
//! observed window (drift value, candidate gain, migration cost, verdict
//! with reason), and the planner emits one per phase event (LPT placement,
//! refinement rounds, lazy-greedy commits, delta/queue rebuilds, per-tier
//! BvN phases). Records are collected by the [`super::Tracer`] they were
//! emitted through, so spans and decisions share one clock and one export.
//!
//! The replan gate's verdict vocabulary now spans four trigger families:
//! drift (`keep_low_drift`, `commit`, `skipped_gain`, `skipped_cost`,
//! `skipped_cooldown`), SLO (`slo_triggered`, `slo_suppressed_cooldown`),
//! cluster membership/elasticity (`repair_promoted` at a failure's
//! in-window promotion, `gpu_drained`/`gpu_joined` at the event,
//! `repair_replanned` when the repair commits, `scaled_up`, and
//! `consolidated`), and gray failures (`degrade_detected` when the
//! [`super::degrade::DegradationDetector`]'s confirmation is adopted — with
//! the inferred `compute_scale`/`bandwidth_scale` and whether it
//! `escalated` past the severity floor into the failure path —
//! `degrade_replanned` when the effective-rate replan commits, and
//! `degrade_recovered` when a straggler returns to nominal) — the CI
//! fault-injection and straggler smoke legs grep exactly this vocabulary
//! out of the exported trace.
//!
//! Field values are [`Json`] so records stay schema-free: a consumer greps
//! on `kind` and reads the fields it knows. Ordering of fields is preserved
//! (they serialize as `[key, value]` pairs, not as a key-sorted object).

use crate::util::Json;

/// One structured decision: what was decided, when, and on which evidence.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    /// Time of the decision in the emitting tracer's clock (µs).
    pub t_us: u64,
    /// Record type, dot-namespaced by subsystem (e.g.
    /// `"coordinator.replan_gate"`, `"planner.refine_round"`).
    pub kind: String,
    /// Ordered evidence fields.
    pub fields: Vec<(String, Json)>,
}

impl DecisionRecord {
    /// Field lookup by key (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// JSON form: `{"type":"decision","ts_us":..,"kind":..,"fields":[[k,v],..]}`.
    /// Fields serialize as an array of pairs so their order survives the
    /// round trip (a JSON object would re-sort them).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("type", Json::from("decision")),
            ("ts_us", Json::from(self.t_us)),
            ("kind", Json::from(self.kind.as_str())),
            (
                "fields",
                Json::Arr(
                    self.fields
                        .iter()
                        .map(|(k, v)| Json::Arr(vec![Json::from(k.as_str()), v.clone()]))
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line human rendering: `[      123 µs] kind key=value ...`.
    pub fn render(&self) -> String {
        let mut out = format!("[{:>10} µs] {}", self.t_us, self.kind);
        for (k, v) in &self.fields {
            let val = match v {
                Json::Str(s) => s.clone(),
                other => other.to_string_compact(),
            };
            out.push_str(&format!(" {k}={val}"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> DecisionRecord {
        DecisionRecord {
            t_us: 42,
            kind: "coordinator.replan_gate".to_string(),
            fields: vec![
                ("verdict".to_string(), Json::from("keep")),
                ("drift".to_string(), Json::Num(0.25)),
            ],
        }
    }

    #[test]
    fn field_lookup_and_render() {
        let r = record();
        assert_eq!(r.get("verdict"), Some(&Json::from("keep")));
        assert_eq!(r.get("missing"), None);
        let line = r.render();
        assert!(line.contains("coordinator.replan_gate"), "{line}");
        assert!(line.contains("verdict=keep"), "{line}");
        assert!(line.contains("drift=0.25"), "{line}");
    }

    #[test]
    fn json_preserves_field_order() {
        let r = record();
        let j = r.to_json();
        let fields = j.get("fields").and_then(|f| f.as_arr()).unwrap();
        assert_eq!(fields.len(), 2);
        // verdict was inserted first and must serialize first
        assert_eq!(fields[0].as_arr().unwrap()[0], Json::from("verdict"));
        assert_eq!(fields[1].as_arr().unwrap()[0], Json::from("drift"));
    }
}
