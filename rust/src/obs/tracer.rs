//! Span tracing with an injectable clock.
//!
//! A [`Tracer`] records a tree of begin/end **spans** — named, timestamped
//! intervals carrying string labels and integer counters — plus the
//! [`DecisionRecord`]s emitted through it. Two clocks are supported:
//!
//! * **wall clock** ([`Tracer::wall`]) — spans are timed with
//!   [`std::time::Instant`] relative to the tracer's creation; this is what
//!   the planner and the `profile` subcommand use.
//! * **sim time** ([`Tracer::sim`]) — the discrete-event simulators *drive*
//!   the clock ([`Tracer::set_sim_time_us`]), so two runs of the same seeded
//!   simulation produce **byte-identical** traces: diffable, committable,
//!   assertable.
//!
//! A **disabled** tracer ([`Tracer::disabled`], also [`Default`]) is a
//! no-op: every call returns immediately, so instrumented hot paths cost one
//! `Option` check when tracing is off. Tracing is strictly observational —
//! no planner or scheduler decision ever reads tracer state — which is what
//! the tracing-on/off bit-for-bit property test pins.
//!
//! Export targets:
//! * [`Tracer::to_chrome_string`] — Chrome trace-event-format JSON
//!   (`chrome://tracing`, <https://ui.perfetto.dev>): spans as complete
//!   (`"ph":"X"`) events, decisions as instant (`"ph":"i"`) events;
//! * [`Tracer::to_jsonl`] — one JSON record per line for `grep`/`jq`;
//! * [`parse_chrome_trace`] — the inverse of the Chrome export for spans,
//!   used by the round-trip test (emit → serialize → parse → identical).

use super::decision::DecisionRecord;
use crate::util::Json;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// One completed (or still-open) span.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Span name, dot-namespaced by subsystem (e.g. `"planner.refine"`).
    pub name: String,
    /// Start time (µs, tracer clock).
    pub start_us: u64,
    /// Duration (µs); `0` until the span ends.
    pub dur_us: u64,
    /// Index of the enclosing span in the tracer's span list.
    pub parent: Option<usize>,
    /// Nesting depth (root = 0). Derived from `parent`.
    pub depth: u32,
    /// Track (Chrome `tid`) the span renders on; lets one trace carry
    /// several side-by-side timelines (e.g. one per serving strategy).
    pub track: u32,
    /// String labels, in insertion order.
    pub labels: Vec<(String, String)>,
    /// Integer counters, in insertion order.
    pub counters: Vec<(String, i64)>,
}

#[derive(Debug)]
enum ClockSource {
    /// Wall clock anchored at tracer creation.
    Wall(Instant),
    /// Simulation time, advanced explicitly (µs).
    Sim(u64),
}

#[derive(Debug)]
struct TracerInner {
    clock: ClockSource,
    spans: Vec<Span>,
    /// Stack of open span indices (the top is the current parent).
    open: Vec<usize>,
    decisions: Vec<DecisionRecord>,
    track: u32,
}

impl TracerInner {
    fn now_us(&self) -> u64 {
        match &self.clock {
            ClockSource::Wall(anchor) => anchor.elapsed().as_micros() as u64,
            ClockSource::Sim(t) => *t,
        }
    }
}

/// Identifier of a span within its tracer. The disabled tracer hands out an
/// inert sentinel, so ids can be passed around without enablement checks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanId(usize);

const NO_SPAN: usize = usize::MAX;

/// Cheap-to-clone tracing handle (clones share the underlying buffer).
/// See the module docs for the span model and the clock contract.
#[derive(Debug, Clone, Default)]
pub struct Tracer(Option<Rc<RefCell<TracerInner>>>);

impl Tracer {
    /// The no-op tracer: records nothing, costs one `Option` check per call.
    pub fn disabled() -> Tracer {
        Tracer(None)
    }

    /// Wall-clock tracer (timestamps relative to this call).
    pub fn wall() -> Tracer {
        Tracer::with_clock(ClockSource::Wall(Instant::now()))
    }

    /// Sim-time tracer starting at t = 0 µs; advance it with
    /// [`Tracer::set_sim_time_us`].
    pub fn sim() -> Tracer {
        Tracer::with_clock(ClockSource::Sim(0))
    }

    fn with_clock(clock: ClockSource) -> Tracer {
        Tracer(Some(Rc::new(RefCell::new(TracerInner {
            clock,
            spans: Vec::new(),
            open: Vec::new(),
            decisions: Vec::new(),
            track: 1,
        }))))
    }

    /// Whether this tracer records anything.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Advance a sim-time tracer's clock to `t_us`. No-op on wall-clock and
    /// disabled tracers (the wall clock cannot be steered).
    pub fn set_sim_time_us(&self, t_us: u64) {
        if let Some(inner) = &self.0 {
            let mut inner = inner.borrow_mut();
            if let ClockSource::Sim(t) = &mut inner.clock {
                *t = t_us;
            }
        }
    }

    /// Set the track (Chrome `tid`) newly begun spans render on.
    pub fn set_track(&self, track: u32) {
        if let Some(inner) = &self.0 {
            inner.borrow_mut().track = track;
        }
    }

    /// Current time on the tracer's clock (µs); 0 when disabled.
    pub fn now_us(&self) -> u64 {
        match &self.0 {
            Some(inner) => inner.borrow().now_us(),
            None => 0,
        }
    }

    /// Open a span. Pair with [`Tracer::end`], or prefer [`Tracer::span`]
    /// for scope-shaped regions.
    pub fn begin(&self, name: &str) -> SpanId {
        let Some(inner) = &self.0 else {
            return SpanId(NO_SPAN);
        };
        let mut inner = inner.borrow_mut();
        let now = inner.now_us();
        let parent = inner.open.last().copied();
        let depth = inner.open.len() as u32;
        let track = inner.track;
        let idx = inner.spans.len();
        inner.spans.push(Span {
            name: name.to_string(),
            start_us: now,
            dur_us: 0,
            parent,
            depth,
            track,
            labels: Vec::new(),
            counters: Vec::new(),
        });
        inner.open.push(idx);
        SpanId(idx)
    }

    /// Close a span (its duration becomes now − start).
    pub fn end(&self, id: SpanId) {
        let Some(inner) = &self.0 else {
            return;
        };
        if id.0 == NO_SPAN {
            return;
        }
        let mut inner = inner.borrow_mut();
        let now = inner.now_us();
        if let Some(pos) = inner.open.iter().rposition(|&i| i == id.0) {
            inner.open.remove(pos);
        }
        let span = &mut inner.spans[id.0];
        span.dur_us = now.saturating_sub(span.start_us);
    }

    /// RAII span: opens now, ends when the returned scope drops.
    pub fn span(&self, name: &str) -> SpanScope {
        SpanScope {
            tracer: self.clone(),
            id: self.begin(name),
        }
    }

    /// Attach a string label to an open or closed span.
    pub fn label(&self, id: SpanId, key: &str, value: &str) {
        let Some(inner) = &self.0 else {
            return;
        };
        if id.0 == NO_SPAN {
            return;
        }
        inner.borrow_mut().spans[id.0]
            .labels
            .push((key.to_string(), value.to_string()));
    }

    /// Add `delta` to an integer counter on a span (created at 0 on first
    /// touch; insertion order is preserved).
    pub fn counter(&self, id: SpanId, key: &str, delta: i64) {
        let Some(inner) = &self.0 else {
            return;
        };
        if id.0 == NO_SPAN {
            return;
        }
        let mut inner = inner.borrow_mut();
        let counters = &mut inner.spans[id.0].counters;
        match counters.iter_mut().find(|(k, _)| k == key) {
            Some((_, v)) => *v += delta,
            None => counters.push((key.to_string(), delta)),
        }
    }

    /// Record a structured decision at the current clock time.
    pub fn decision(&self, kind: &str, fields: Vec<(&str, Json)>) {
        let Some(inner) = &self.0 else {
            return;
        };
        let mut inner = inner.borrow_mut();
        let t_us = inner.now_us();
        inner.decisions.push(DecisionRecord {
            t_us,
            kind: kind.to_string(),
            fields: fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        });
    }

    /// Snapshot of all spans recorded so far (creation order).
    pub fn spans(&self) -> Vec<Span> {
        match &self.0 {
            Some(inner) => inner.borrow().spans.clone(),
            None => Vec::new(),
        }
    }

    /// Snapshot of all decision records (emission order).
    pub fn decisions(&self) -> Vec<DecisionRecord> {
        match &self.0 {
            Some(inner) => inner.borrow().decisions.clone(),
            None => Vec::new(),
        }
    }

    /// Chrome trace-event-format document. Spans become complete events
    /// (`"ph":"X"`, timestamps in µs); each carries `args.seq`/`args.parent`
    /// so [`parse_chrome_trace`] reconstructs the exact span tree. Decisions
    /// become instant events (`"ph":"i"`).
    pub fn to_chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for (i, s) in self.spans().iter().enumerate() {
            let parent = match s.parent {
                Some(p) => Json::Num(p as f64),
                None => Json::Num(-1.0),
            };
            let args = Json::obj(vec![
                ("seq", Json::from(i)),
                ("parent", parent),
                ("labels", pairs_str(&s.labels)),
                ("counters", pairs_i64(&s.counters)),
            ]);
            events.push(Json::obj(vec![
                ("name", Json::from(s.name.as_str())),
                ("cat", Json::from("aurora")),
                ("ph", Json::from("X")),
                ("ts", Json::from(s.start_us)),
                ("dur", Json::from(s.dur_us)),
                ("pid", Json::from(1u64)),
                ("tid", Json::from(s.track as u64)),
                ("args", args),
            ]));
        }
        for d in self.decisions() {
            let fields = Json::Arr(
                d.fields
                    .iter()
                    .map(|(k, v)| Json::Arr(vec![Json::from(k.as_str()), v.clone()]))
                    .collect(),
            );
            events.push(Json::obj(vec![
                ("name", Json::from(d.kind.as_str())),
                ("cat", Json::from("decision")),
                ("ph", Json::from("i")),
                ("s", Json::from("g")),
                ("ts", Json::from(d.t_us)),
                ("pid", Json::from(1u64)),
                ("tid", Json::from(1u64)),
                ("args", Json::obj(vec![("fields", fields)])),
            ]));
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::from("ms")),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// [`Tracer::to_chrome_trace`] serialized compactly. Deterministic for a
    /// sim-time tracer (object keys are ordered, numbers format stably).
    pub fn to_chrome_string(&self) -> String {
        self.to_chrome_trace().to_string_compact()
    }

    /// JSONL export: one record per line — spans (creation order) then
    /// decisions (emission order), each self-describing via `"type"`.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for (i, s) in self.spans().iter().enumerate() {
            let parent = match s.parent {
                Some(p) => Json::Num(p as f64),
                None => Json::Null,
            };
            let line = Json::obj(vec![
                ("type", Json::from("span")),
                ("seq", Json::from(i)),
                ("name", Json::from(s.name.as_str())),
                ("ts_us", Json::from(s.start_us)),
                ("dur_us", Json::from(s.dur_us)),
                ("parent", parent),
                ("track", Json::from(s.track as u64)),
                ("labels", pairs_str(&s.labels)),
                ("counters", pairs_i64(&s.counters)),
            ]);
            out.push_str(&line.to_string_compact());
            out.push('\n');
        }
        for d in self.decisions() {
            out.push_str(&d.to_json().to_string_compact());
            out.push('\n');
        }
        out
    }
}

/// RAII guard returned by [`Tracer::span`]; ends the span on drop.
#[derive(Debug)]
pub struct SpanScope {
    tracer: Tracer,
    id: SpanId,
}

impl SpanScope {
    /// The guarded span's id, for attaching labels and counters.
    pub fn id(&self) -> SpanId {
        self.id
    }
}

impl Drop for SpanScope {
    fn drop(&mut self) {
        self.tracer.end(self.id);
    }
}

fn pairs_str(pairs: &[(String, String)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(k, v)| Json::Arr(vec![Json::from(k.as_str()), Json::from(v.as_str())]))
            .collect(),
    )
}

fn pairs_i64(pairs: &[(String, i64)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(k, v)| Json::Arr(vec![Json::from(k.as_str()), Json::Num(*v as f64)]))
            .collect(),
    )
}

fn parse_pairs_str(v: Option<&Json>) -> Result<Vec<(String, String)>, String> {
    let arr = v
        .and_then(|j| j.as_arr())
        .ok_or_else(|| "missing label array".to_string())?;
    arr.iter()
        .map(|pair| {
            let kv = pair.as_arr().ok_or("label pair is not an array")?;
            match (kv.first().and_then(|k| k.as_str()), kv.get(1).and_then(|x| x.as_str())) {
                (Some(k), Some(x)) => Ok((k.to_string(), x.to_string())),
                _ => Err("label pair is not [string, string]".to_string()),
            }
        })
        .collect()
}

fn parse_pairs_i64(v: Option<&Json>) -> Result<Vec<(String, i64)>, String> {
    let arr = v
        .and_then(|j| j.as_arr())
        .ok_or_else(|| "missing counter array".to_string())?;
    arr.iter()
        .map(|pair| {
            let kv = pair.as_arr().ok_or("counter pair is not an array")?;
            match (kv.first().and_then(|k| k.as_str()), kv.get(1).and_then(|x| x.as_f64())) {
                (Some(k), Some(x)) => Ok((k.to_string(), x as i64)),
                _ => Err("counter pair is not [string, number]".to_string()),
            }
        })
        .collect()
}

/// Parse a Chrome trace-event document produced by
/// [`Tracer::to_chrome_trace`] back into its span list — the inverse used by
/// the export round-trip test. Instant (decision) events are skipped; spans
/// are returned in their original creation (`args.seq`) order with the
/// parent/depth tree reconstructed.
pub fn parse_chrome_trace(text: &str) -> Result<Vec<Span>, String> {
    let doc = Json::parse(text).map_err(|e| e.to_string())?;
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("no traceEvents array")?;
    let mut spans: Vec<(usize, Span)> = Vec::new();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()) != Some("X") {
            continue;
        }
        let name = ev
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or("span event without a name")?
            .to_string();
        let start_us = ev.get("ts").and_then(|t| t.as_u64()).ok_or("span without ts")?;
        let dur_us = ev.get("dur").and_then(|d| d.as_u64()).ok_or("span without dur")?;
        let track = ev.get("tid").and_then(|t| t.as_u64()).unwrap_or(1) as u32;
        let args = ev.get("args").ok_or("span without args")?;
        let seq = args
            .get("seq")
            .and_then(|s| s.as_u64())
            .ok_or("span without args.seq")? as usize;
        let parent = match args.get("parent").and_then(|p| p.as_f64()) {
            Some(p) if p >= 0.0 => Some(p as usize),
            Some(_) => None,
            None => return Err("span without args.parent".to_string()),
        };
        let labels = parse_pairs_str(args.get("labels"))?;
        let counters = parse_pairs_i64(args.get("counters"))?;
        spans.push((
            seq,
            Span {
                name,
                start_us,
                dur_us,
                parent,
                depth: 0,
                track,
                labels,
                counters,
            },
        ));
    }
    spans.sort_by_key(|(seq, _)| *seq);
    for (pos, (seq, _)) in spans.iter().enumerate() {
        if *seq != pos {
            return Err(format!("span seq {seq} out of order (expected {pos})"));
        }
    }
    let mut out: Vec<Span> = spans.into_iter().map(|(_, s)| s).collect();
    // Depth is derived: parents always precede children in seq order.
    for i in 0..out.len() {
        let depth = match out[i].parent {
            Some(p) if p < i => out[p].depth + 1,
            Some(p) => return Err(format!("span {i} references later parent {p}")),
            None => 0,
        };
        out[i].depth = depth;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert() {
        let tr = Tracer::disabled();
        assert!(!tr.is_enabled());
        let id = tr.begin("x");
        tr.counter(id, "n", 3);
        tr.label(id, "k", "v");
        tr.end(id);
        tr.decision("d", vec![("a", Json::from(1u64))]);
        assert!(tr.spans().is_empty());
        assert!(tr.decisions().is_empty());
        assert_eq!(tr.to_jsonl(), "");
    }

    #[test]
    fn sim_clock_drives_span_times() {
        let tr = Tracer::sim();
        tr.set_sim_time_us(100);
        let outer = tr.begin("outer");
        tr.set_sim_time_us(150);
        let inner = tr.begin("inner");
        tr.counter(inner, "tokens", 7);
        tr.counter(inner, "tokens", 5);
        tr.set_sim_time_us(200);
        tr.end(inner);
        tr.set_sim_time_us(300);
        tr.end(outer);
        let spans = tr.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].start_us, 100);
        assert_eq!(spans[0].dur_us, 200);
        assert_eq!(spans[0].parent, None);
        assert_eq!(spans[1].start_us, 150);
        assert_eq!(spans[1].dur_us, 50);
        assert_eq!(spans[1].parent, Some(0));
        assert_eq!(spans[1].depth, 1);
        assert_eq!(spans[1].counters, vec![("tokens".to_string(), 12)]);
    }

    #[test]
    fn span_scope_ends_on_drop() {
        let tr = Tracer::sim();
        {
            let sp = tr.span("scoped");
            tr.label(sp.id(), "phase", "one");
            tr.set_sim_time_us(40);
        }
        let spans = tr.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].dur_us, 40);
        assert_eq!(spans[0].labels, vec![("phase".to_string(), "one".to_string())]);
    }

    #[test]
    fn chrome_round_trip_preserves_the_span_tree() {
        let tr = Tracer::sim();
        let a = tr.begin("a");
        tr.set_sim_time_us(10);
        let b = tr.begin("b");
        tr.label(b, "z_last", "1");
        tr.label(b, "a_first", "2"); // order ≠ sorted order: must survive
        tr.counter(b, "count", 5);
        tr.set_sim_time_us(20);
        tr.end(b);
        tr.end(a);
        tr.decision("gate", vec![("verdict", Json::from("keep"))]);
        let text = tr.to_chrome_string();
        let parsed = parse_chrome_trace(&text).unwrap();
        assert_eq!(parsed, tr.spans());
    }

    #[test]
    fn wall_clock_spans_have_monotone_times() {
        let tr = Tracer::wall();
        let id = tr.begin("w");
        std::thread::sleep(std::time::Duration::from_millis(2));
        tr.end(id);
        let spans = tr.spans();
        assert!(spans[0].dur_us >= 1_000, "slept 2 ms, span {} µs", spans[0].dur_us);
        // steering the sim clock is a no-op on a wall tracer
        tr.set_sim_time_us(0);
    }

    #[test]
    fn clones_share_the_buffer() {
        let tr = Tracer::sim();
        let clone = tr.clone();
        let id = clone.begin("shared");
        clone.end(id);
        assert_eq!(tr.spans().len(), 1);
    }
}
