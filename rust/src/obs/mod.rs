//! Observability: span tracing, metrics, and decision logs.
//!
//! Three pillars, one shared design:
//!
//! * **Span tracing** ([`tracer`]) — a [`Tracer`] records begin/end spans
//!   with labels and integer counters under an injectable clock (wall clock
//!   for the planner, sim time for the discrete-event simulators), and
//!   exports Chrome trace-event-format JSON ([`Tracer::to_chrome_string`],
//!   openable in `chrome://tracing` / Perfetto) and JSONL
//!   ([`Tracer::to_jsonl`]).
//! * **Metrics** ([`metrics`]) — a [`MetricsRegistry`] of counters, gauges,
//!   and log-bucketed [`Histogram`]s with a deterministic JSON snapshot;
//!   also home of the typed-error percentile helpers that `serve::metrics`
//!   re-exports.
//! * **Decision logs** ([`decision`]) — [`DecisionRecord`]s explain *why*:
//!   the coordinator's replan gate emits one per window (drift, candidate
//!   gain, migration cost, verdict), the planner one per phase event.
//!
//! Every handle ([`Tracer`], [`MetricsRegistry`]) is cheap to clone and has
//! a `disabled()` constructor that is a total no-op, so instrumentation
//! lives permanently on the planner/scheduler/coordinator paths at zero
//! cost when off — and, critically, **tracing never influences results**:
//! an integration property test pins that planning with tracing on versus
//! off yields bit-for-bit identical deployments and schedules.
//!
//! Handles are intentionally **not** `Send`/`Sync` (`Rc<RefCell<..>>`):
//! they must never be captured by `util::par::par_map` closures. Parallel
//! sweeps stay untraced internally; their enclosing phase span records the
//! aggregate.
//!
//! The [`profile`] module drives a full plan + schedule run under a
//! wall-clock tracer and renders the per-phase time breakdown table behind
//! the CLI `profile` subcommand.
//!
//! On top of the substrate sit two analysis layers:
//!
//! * **Timelines** ([`timeline`]) — a [`TimelineRecorder`] threaded through
//!   every simulator attributes each GPU-millisecond to a typed segment
//!   (compute / comm send / comm recv / sync-wait / swap-drain / idle) per
//!   GPU engine and per access link, derives utilization and per-kind
//!   breakdowns, and exports multi-track Chrome traces. Same no-op
//!   contract as the tracer: recording never changes simulator results.
//! * **SLO watchdog** ([`slo`]) — a [`SloMonitor`] tracks rolling-window
//!   p50/p95/p99 of serving latencies and flags p99 violations, which the
//!   coordinator turns into emergency replans (decision verdicts
//!   `slo_triggered` / `slo_suppressed_cooldown`).
//! * **Degradation detector** ([`degrade`]) — a [`DegradationDetector`]
//!   infers per-GPU effective compute/bandwidth scales by ratioing observed
//!   timeline segment durations against the plan-time cost model's
//!   prediction (EWMA-smoothed, hysteresis bands, K-consecutive-window
//!   confirmation), feeding the coordinator's gray-failure repair path
//!   (verdicts `degrade_detected` / `degrade_replanned` /
//!   `degrade_recovered`).

pub mod decision;
pub mod degrade;
pub mod metrics;
pub mod profile;
pub mod slo;
pub mod timeline;
pub mod tracer;

pub use decision::DecisionRecord;
pub use degrade::{DegradationDetector, DegradeConfig, DetectorEvent, WindowObservation};
pub use metrics::{p50_p95_p99, percentile, Histogram, MetricsError, MetricsRegistry};
pub use profile::{run_profile, ProfileConfig, ProfileReport};
pub use slo::{SloMonitor, SloStatus};
pub use timeline::{
    mean_busy_fraction, schedule_round_occupancy, Breakdown, GpuTimeline, KindShare, LinkTimeline,
    RoundOccupancy, Segment, SegmentKind, TimelineRecorder, Timelines,
};
pub use tracer::{parse_chrome_trace, Span, SpanId, SpanScope, Tracer};
