//! SLO watchdog: rolling tail-latency quantiles + violation detection.
//!
//! [`SloMonitor`] watches per-window serving latencies and answers one
//! question: *is the rolling p99 above the target?* It keeps
//!
//! * a bounded rolling window of the most recent finite samples, over which
//!   [`SloMonitor::status`] computes exact nearest-rank p50/p95/p99 (via
//!   [`crate::obs::metrics::p50_p95_p99`]), and
//! * a cumulative [`Histogram`] of the full stream for cheap long-run
//!   quantiles, reusing the metrics substrate.
//!
//! **Trigger semantics** (pinned by a property test): the monitor is
//! violating iff the rolling p99 strictly exceeds the target — no
//! hysteresis, no smoothing. The burn rate is reported alongside as a
//! diagnostic: the fraction of window samples over target divided by the
//! 1% error budget, in the style of burn-rate SLO alerting (≥ 1 means the
//! budget is being consumed faster than sustainable). Non-finite or
//! negative samples are dropped and counted, mirroring [`Histogram`]'s
//! discipline, so NaN/∞-laced streams cannot poison the quantiles.
//!
//! The coordinator owns one monitor when configured with a latency SLO
//! (`CoordinatorConfig::slo_p99_ms`) and uses a violation as an *emergency*
//! replan trigger — see `coordinator` module docs for how it interacts
//! with the drift trigger and the cooldown gate.

use crate::obs::metrics::{p50_p95_p99, Histogram};
use std::collections::VecDeque;

/// Quantiles and violation verdict over the current rolling window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloStatus {
    /// Rolling-window median latency (ms); 0 when the window is empty.
    pub p50_ms: f64,
    /// Rolling-window p95 (ms).
    pub p95_ms: f64,
    /// Rolling-window p99 (ms).
    pub p99_ms: f64,
    /// `p99_ms > target` — the replan trigger.
    pub violating: bool,
    /// Fraction of window samples over target divided by the 1% budget.
    pub burn_rate: f64,
}

/// Rolling-window p50/p95/p99 tracker with a p99 violation trigger.
#[derive(Debug, Clone)]
pub struct SloMonitor {
    target_p99_ms: f64,
    window: usize,
    samples: VecDeque<f64>,
    hist: Histogram,
    dropped: u64,
    violations: u64,
}

impl SloMonitor {
    /// Monitor targeting `target_p99_ms` over a rolling window of `window`
    /// samples. `target_p99_ms` must be positive and finite; `window ≥ 1`.
    pub fn new(target_p99_ms: f64, window: usize) -> Self {
        assert!(
            target_p99_ms.is_finite() && target_p99_ms > 0.0,
            "SLO target must be positive and finite"
        );
        assert!(window >= 1, "rolling window must hold at least one sample");
        Self {
            target_p99_ms,
            window,
            samples: VecDeque::with_capacity(window),
            hist: Histogram::new(),
            dropped: 0,
            violations: 0,
        }
    }

    /// Record one window latency and return the updated status. Non-finite
    /// or negative samples are dropped (counted) and leave the window
    /// unchanged.
    pub fn observe(&mut self, latency_ms: f64) -> SloStatus {
        if latency_ms.is_finite() && latency_ms >= 0.0 {
            self.hist.record(latency_ms);
            if self.samples.len() == self.window {
                self.samples.pop_front();
            }
            self.samples.push_back(latency_ms);
        } else {
            self.dropped += 1;
        }
        let st = self.status();
        if st.violating {
            self.violations += 1;
        }
        st
    }

    /// Current rolling-window status without recording anything.
    pub fn status(&self) -> SloStatus {
        if self.samples.is_empty() {
            return SloStatus {
                p50_ms: 0.0,
                p95_ms: 0.0,
                p99_ms: 0.0,
                violating: false,
                burn_rate: 0.0,
            };
        }
        let xs: Vec<f64> = self.samples.iter().copied().collect();
        let (p50, p95, p99) = p50_p95_p99(&xs).expect("window holds only finite samples");
        let over = xs.iter().filter(|&&x| x > self.target_p99_ms).count();
        SloStatus {
            p50_ms: p50,
            p95_ms: p95,
            p99_ms: p99,
            violating: p99 > self.target_p99_ms,
            burn_rate: over as f64 / xs.len() as f64 / 0.01,
        }
    }

    /// Whether the rolling p99 currently exceeds the target.
    pub fn is_violating(&self) -> bool {
        self.status().violating
    }

    /// Forget the rolling window (e.g. after a replan installs a new
    /// deployment) — the cumulative histogram and counters are kept.
    pub fn reset_window(&mut self) {
        self.samples.clear();
    }

    /// Configured p99 target (ms).
    pub fn target_p99_ms(&self) -> f64 {
        self.target_p99_ms
    }

    /// Rolling window capacity in samples.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Samples currently in the rolling window.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no finite sample has been observed since the last reset.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Non-finite/negative samples dropped so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Observations whose updated status was violating.
    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Cumulative full-stream latency histogram.
    pub fn histogram(&self) -> &Histogram {
        &self.hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_monitor_not_violating() {
        let m = SloMonitor::new(10.0, 8);
        assert!(!m.is_violating());
        assert_eq!(m.status().p99_ms, 0.0);
        assert!(m.is_empty());
    }

    #[test]
    fn fires_iff_rolling_p99_exceeds_target() {
        let mut m = SloMonitor::new(10.0, 4);
        for _ in 0..4 {
            assert!(!m.observe(5.0).violating);
        }
        // one spike: p99 (nearest-rank max of 4 samples) jumps above target
        let st = m.observe(50.0);
        assert!(st.violating && st.p99_ms == 50.0);
        // spike rolls out of the window after 4 more good samples
        for _ in 0..3 {
            assert!(m.observe(5.0).violating);
        }
        assert!(!m.observe(5.0).violating);
    }

    #[test]
    fn adversarial_samples_dropped_not_counted() {
        let mut m = SloMonitor::new(10.0, 4);
        m.observe(2.0);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let st = m.observe(bad);
            assert!(!st.violating, "{bad} must not trip the SLO");
        }
        assert_eq!(m.dropped(), 4);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn exactly_at_target_is_not_a_violation() {
        let mut m = SloMonitor::new(10.0, 4);
        assert!(!m.observe(10.0).violating);
        assert!(m.observe(10.0 + 1e-9).violating);
    }

    #[test]
    fn reset_window_keeps_history() {
        let mut m = SloMonitor::new(1.0, 4);
        m.observe(5.0);
        assert!(m.is_violating());
        m.reset_window();
        assert!(!m.is_violating());
        assert_eq!(m.histogram().count(), 1);
        assert!(m.violations() >= 1);
    }

    #[test]
    fn burn_rate_scales_with_violation_fraction() {
        let mut m = SloMonitor::new(10.0, 4);
        m.observe(5.0);
        m.observe(5.0);
        m.observe(50.0);
        let st = m.observe(50.0);
        // half the window over target against a 1% budget
        assert!((st.burn_rate - 50.0).abs() < 1e-9);
    }
}
