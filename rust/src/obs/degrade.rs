//! Gray-failure detection from observed timelines.
//!
//! A straggling GPU never announces itself: thermal throttling, ECC retries,
//! and flaky NICs just stretch its compute and link segments, and every
//! *peer* pays for it as `SyncWait` growth at the synchronous all-to-all
//! barriers. The [`DegradationDetector`] closes the loop without being told
//! the truth, by comparing what the timeline recorder *observed* against
//! what the plan-time cost model *predicted* for the same window:
//!
//! ```text
//! ratio[g] = predicted_busy_ms[g] / observed_busy_ms[g]
//! ```
//!
//! Busy totals (compute time on the engine track, uplink+downlink occupancy
//! on the port track) are barrier-independent — a GPU slowed to 0.4× shows
//! `ratio ≈ 0.4` on its own track while its peers stay at exactly 1.0, no
//! matter how the waits shuffle. The ratio is therefore a direct estimate of
//! the GPU's effective-rate scale ([`crate::cluster::GpuScales`]).
//!
//! Raw ratios are noisy (measurement jitter, model error), so the detector
//! is deliberately sluggish:
//!
//! * **EWMA smoothing** per GPU per channel (`ewma_alpha`);
//! * **hysteresis bands**: a GPU is suspected only while its smoothed ratio
//!   sits below `detect_below`, and considered healthy again only above
//!   `recover_above` (`detect_below < recover_above`, so the bands cannot
//!   chatter);
//! * **K-consecutive-window confirmation** (`confirm_windows`): a state flip
//!   needs K windows in a row inside the new band. Small-amplitude noise
//!   (within the hysteresis gap) therefore *never* flaps the detector.
//!
//! Confirmed scales feed [`crate::coordinator::Coordinator::observe_degradation`],
//! which re-prices deployment candidates on the effective cluster.

use super::timeline::Timelines;

/// Inferred scales never drop below this floor — a ratio near zero means
/// the measurement broke, not that the GPU runs at 0×.
const MIN_SCALE: f64 = 0.05;

/// Tuning for the [`DegradationDetector`]'s smoothing and hysteresis.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeConfig {
    /// EWMA weight of the newest window's ratio (1.0 = no smoothing).
    pub ewma_alpha: f64,
    /// Suspect threshold: smoothed ratio below this counts toward a
    /// degradation confirmation.
    pub detect_below: f64,
    /// Healthy threshold: smoothed ratio above this counts toward a
    /// recovery confirmation. Must exceed `detect_below`.
    pub recover_above: f64,
    /// Consecutive windows inside a band required to flip state.
    pub confirm_windows: usize,
    /// Segment-duration floor (ms): busy totals below this on either side
    /// are too small to measure and report ratio 1.0.
    pub min_ms: f64,
}

impl Default for DegradeConfig {
    fn default() -> DegradeConfig {
        DegradeConfig {
            ewma_alpha: 0.5,
            detect_below: 0.9,
            recover_above: 0.97,
            confirm_windows: 2,
            min_ms: 1e-3,
        }
    }
}

impl DegradeConfig {
    fn validate(&self) {
        assert!(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0);
        assert!(self.detect_below > 0.0 && self.detect_below < self.recover_above);
        assert!(self.recover_above <= 1.0);
        assert!(self.confirm_windows >= 1);
        assert!(self.min_ms >= 0.0);
    }
}

/// One window's observed-vs-predicted ratios, per GPU: the detector's input.
/// Values near 1.0 mean the GPU ran at the modeled rate; a compute straggler
/// at 0.4× shows `compute_ratio ≈ 0.4` on its own row.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowObservation {
    /// Per-GPU predicted/observed engine compute-time ratio.
    pub compute_ratio: Vec<f64>,
    /// Per-GPU predicted/observed port busy-time (uplink+downlink) ratio.
    pub link_ratio: Vec<f64>,
}

impl WindowObservation {
    /// Build from a recorded (observed) and a re-simulated nominal
    /// (predicted) timeline of the *same* window. Busy totals below `min_ms`
    /// on either side report 1.0 — too small to measure.
    pub fn from_timelines(observed: &Timelines, predicted: &Timelines, min_ms: f64) -> Self {
        assert_eq!(
            observed.gpus.len(),
            predicted.gpus.len(),
            "timelines must cover the same cluster"
        );
        let ratio = |p: f64, o: f64| if p < min_ms || o < min_ms { 1.0 } else { p / o };
        let oc = observed.per_gpu_compute_ms();
        let pc = predicted.per_gpu_compute_ms();
        let ol = observed.per_gpu_link_busy_ms();
        let pl = predicted.per_gpu_link_busy_ms();
        WindowObservation {
            compute_ratio: (0..oc.len()).map(|g| ratio(pc[g], oc[g])).collect(),
            link_ratio: (0..ol.len()).map(|g| ratio(pl[g], ol[g])).collect(),
        }
    }

    /// Cluster size the observation covers.
    pub fn n_gpus(&self) -> usize {
        self.compute_ratio.len()
    }
}

/// A confirmed detector state transition, surfaced to the coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DetectorEvent {
    /// The GPU crossed into confirmed degradation; scales are the current
    /// smoothed estimates (1.0 on a channel that is not itself degraded).
    Degraded {
        /// The degraded GPU.
        gpu: usize,
        /// Inferred effective compute scale, in `[MIN_SCALE, 1]`.
        compute_scale: f64,
        /// Inferred effective bandwidth scale, in `[MIN_SCALE, 1]`.
        bandwidth_scale: f64,
    },
    /// The GPU crossed back into confirmed health.
    Recovered {
        /// The recovered GPU.
        gpu: usize,
    },
}

/// One EWMA + hysteresis state machine (per GPU, per channel).
#[derive(Debug, Clone, PartialEq, Default)]
struct Channel {
    ewma: Option<f64>,
    below_streak: usize,
    above_streak: usize,
    confirmed: bool,
}

impl Channel {
    fn observe(&mut self, ratio: f64, cfg: &DegradeConfig) {
        let e = match self.ewma {
            None => ratio,
            Some(prev) => cfg.ewma_alpha * ratio + (1.0 - cfg.ewma_alpha) * prev,
        };
        self.ewma = Some(e);
        if e < cfg.detect_below {
            self.below_streak += 1;
        } else {
            self.below_streak = 0;
        }
        if e > cfg.recover_above {
            self.above_streak += 1;
        } else {
            self.above_streak = 0;
        }
        if !self.confirmed && self.below_streak >= cfg.confirm_windows {
            self.confirmed = true;
        } else if self.confirmed && self.above_streak >= cfg.confirm_windows {
            self.confirmed = false;
        }
    }

    /// The inferred scale: 1.0 unless confirmed degraded, else the smoothed
    /// ratio clamped into `[MIN_SCALE, 1]`.
    fn scale(&self) -> f64 {
        if self.confirmed {
            self.ewma.unwrap_or(1.0).clamp(MIN_SCALE, 1.0)
        } else {
            1.0
        }
    }
}

/// Per-GPU gray-failure detector: feed one [`WindowObservation`] per served
/// window ([`DegradationDetector::observe`]), read confirmed transitions
/// from the returned [`DetectorEvent`]s and the current inferred
/// [`GpuScales`](crate::cluster::GpuScales) from
/// [`DegradationDetector::scales`]. A GPU is degraded when *either* its
/// compute or its link channel confirms; it recovers when *both* are
/// confirmed healthy again.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradationDetector {
    cfg: DegradeConfig,
    compute: Vec<Channel>,
    link: Vec<Channel>,
    flagged: Vec<bool>,
}

impl DegradationDetector {
    /// A fresh detector over `n_gpus` GPUs.
    pub fn new(n_gpus: usize, cfg: DegradeConfig) -> DegradationDetector {
        assert!(n_gpus > 0);
        cfg.validate();
        DegradationDetector {
            cfg,
            compute: vec![Channel::default(); n_gpus],
            link: vec![Channel::default(); n_gpus],
            flagged: vec![false; n_gpus],
        }
    }

    /// Cluster size the detector covers.
    pub fn n_gpus(&self) -> usize {
        self.compute.len()
    }

    /// True when GPU `g` is in confirmed degradation.
    pub fn is_degraded(&self, g: usize) -> bool {
        self.flagged[g]
    }

    /// The currently inferred effective-rate scales: 1.0 everywhere except
    /// confirmed-degraded channels, which report their smoothed ratio
    /// (always in `(0, 1]`).
    pub fn scales(&self) -> crate::cluster::GpuScales {
        crate::cluster::GpuScales {
            compute: self.compute.iter().map(Channel::scale).collect(),
            bandwidth: self.link.iter().map(Channel::scale).collect(),
        }
    }

    /// Ingest one window's ratios; returns the confirmed state transitions
    /// (empty for the vast majority of windows).
    pub fn observe(&mut self, obs: &WindowObservation) -> Vec<DetectorEvent> {
        assert_eq!(obs.n_gpus(), self.n_gpus(), "observation must cover the cluster");
        let mut events = Vec::new();
        for g in 0..self.n_gpus() {
            self.compute[g].observe(obs.compute_ratio[g], &self.cfg);
            self.link[g].observe(obs.link_ratio[g], &self.cfg);
            let now = self.compute[g].confirmed || self.link[g].confirmed;
            if now && !self.flagged[g] {
                events.push(DetectorEvent::Degraded {
                    gpu: g,
                    compute_scale: self.compute[g].scale(),
                    bandwidth_scale: self.link[g].scale(),
                });
            } else if !now && self.flagged[g] {
                events.push(DetectorEvent::Recovered { gpu: g });
            }
            self.flagged[g] = now;
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, GpuScales};
    use crate::obs::timeline::TimelineRecorder;
    use crate::schedule::SchedulePolicy;
    use crate::sim::{simulate_window_recorded, MoeLayerStats};
    use crate::traffic::zipf_traffic;

    fn obs(n: usize, compute: &[(usize, f64)]) -> WindowObservation {
        let mut o = WindowObservation {
            compute_ratio: vec![1.0; n],
            link_ratio: vec![1.0; n],
        };
        for &(g, r) in compute {
            o.compute_ratio[g] = r;
        }
        o
    }

    #[test]
    fn detector_confirms_after_k_windows_and_recovers() {
        let mut d = DegradationDetector::new(4, DegradeConfig::default());
        // window 1: suspected, not confirmed (K = 2)
        assert!(d.observe(&obs(4, &[(1, 0.4)])).is_empty());
        assert!(!d.is_degraded(1));
        assert!(d.scales().is_nominal(), "no confirmation, no inferred scales");
        // window 2: confirmed, scales reported
        let evs = d.observe(&obs(4, &[(1, 0.4)]));
        assert_eq!(evs.len(), 1);
        match evs[0] {
            DetectorEvent::Degraded {
                gpu,
                compute_scale,
                bandwidth_scale,
            } => {
                assert_eq!(gpu, 1);
                assert!((compute_scale - 0.4).abs() < 1e-9);
                assert_eq!(bandwidth_scale, 1.0);
            }
            _ => panic!("expected Degraded"),
        }
        assert!(d.is_degraded(1));
        let s = d.scales();
        assert!((s.compute[1] - 0.4).abs() < 1e-9);
        for g in [0, 2, 3] {
            assert_eq!(s.compute[g], 1.0);
        }
        // truth recovers: the EWMA climbs back, recovery confirms after it
        // holds above recover_above for K windows
        let mut recovered_at = None;
        for w in 0..12 {
            let evs = d.observe(&obs(4, &[]));
            if evs.iter().any(|e| matches!(e, DetectorEvent::Recovered { gpu: 1 })) {
                recovered_at = Some(w);
                break;
            }
        }
        assert!(recovered_at.is_some(), "detector must eventually recover");
        assert!(!d.is_degraded(1));
        assert!(d.scales().is_nominal());
    }

    #[test]
    fn small_noise_never_flaps() {
        let mut d = DegradationDetector::new(3, DegradeConfig::default());
        // ±5% jitter stays inside the hysteresis gap's reach of 1.0
        for w in 0..50 {
            let jitter = if w % 2 == 0 { 0.95 } else { 1.05 };
            let o = WindowObservation {
                compute_ratio: vec![jitter; 3],
                link_ratio: vec![2.0 - jitter; 3],
            };
            assert!(d.observe(&o).is_empty(), "noise-only input must emit nothing");
        }
        assert!(d.scales().is_nominal());
    }

    #[test]
    fn single_mild_dip_does_not_confirm() {
        let mut d = DegradationDetector::new(2, DegradeConfig::default());
        assert!(d.observe(&obs(2, &[(0, 0.85)])).is_empty());
        for _ in 0..10 {
            assert!(d.observe(&obs(2, &[])).is_empty());
        }
        assert!(!d.is_degraded(0));
    }

    #[test]
    fn link_channel_confirms_independently() {
        let mut d = DegradationDetector::new(2, DegradeConfig::default());
        let o = WindowObservation {
            compute_ratio: vec![1.0, 1.0],
            link_ratio: vec![1.0, 0.5],
        };
        assert!(d.observe(&o).is_empty());
        let evs = d.observe(&o);
        assert!(matches!(
            evs[0],
            DetectorEvent::Degraded {
                gpu: 1,
                compute_scale,
                ..
            } if compute_scale == 1.0
        ));
        assert!((d.scales().bandwidth[1] - 0.5).abs() < 1e-9);
        assert_eq!(d.scales().compute[1], 1.0);
    }

    #[test]
    fn observation_from_timelines_recovers_injected_scales() {
        let stats = MoeLayerStats {
            traffic: zipf_traffic(4, 512, 0.8, 3),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        };
        let cluster = Cluster::homogeneous(4, 100.0);
        let mut rec = TimelineRecorder::new(4);
        simulate_window_recorded(&[&stats], None, &cluster, None, SchedulePolicy::Aurora, &mut rec);
        let predicted = rec.take().unwrap();

        let mut truth = GpuScales::nominal(4);
        truth.set(2, 0.4, 0.5);
        let mut rec = TimelineRecorder::new(4);
        simulate_window_recorded(
            &[&stats],
            None,
            &cluster,
            Some(&truth),
            SchedulePolicy::Aurora,
            &mut rec,
        );
        let observed = rec.take().unwrap();

        let o = WindowObservation::from_timelines(&observed, &predicted, 1e-3);
        assert!((o.compute_ratio[2] - 0.4).abs() < 1e-9);
        assert!((o.link_ratio[2] - 0.5).abs() < 1e-9);
        for g in [0, 1, 3] {
            assert!((o.compute_ratio[g] - 1.0).abs() < 1e-9);
            assert!((o.link_ratio[g] - 1.0).abs() < 1e-9);
        }
    }
}
