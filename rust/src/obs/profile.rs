//! Self-contained plan + schedule profiling run (the CLI `profile`
//! subcommand).
//!
//! [`run_profile`] builds a synthetic Zipf-skewed single-model workload on a
//! homogeneous cluster whose topology is derived from the GPU count the same
//! way the bench harness shapes its large cases (8 GPUs per rack, 8 racks
//! per pod once the fabric is big enough to have pods), runs the planner and
//! the hierarchical scheduler under a wall-clock [`Tracer`], and returns a
//! [`ProfileReport`]: the per-phase time breakdown table plus the raw tracer
//! for Chrome-trace / JSONL export.

use crate::cluster::{Cluster, Topology};
use crate::eval::skewed_workload;
use crate::planner::{Planner, ReplicationConfig};
use crate::schedule::{aurora_schedule_traced, hierarchical_schedule_traced};
use crate::trace::ModelTrace;

use super::tracer::{Span, Tracer};

/// Per-GPU bandwidth (tokens/ms) of the synthetic profiling cluster — the
/// same figure the bench harness uses.
const PROFILE_BW: f64 = 800.0;

/// Shape of the synthetic profiling workload.
#[derive(Debug, Clone)]
pub struct ProfileConfig {
    /// Cluster size (one expert per GPU). Default 128.
    pub gpus: usize,
    /// Zipf skew of the routing traffic.
    pub skew: f64,
    /// Max copies per expert; ≥ 2 additionally profiles the lazy-greedy
    /// replication pass, 1 profiles placement + refinement only.
    pub replicas: usize,
    /// Workload RNG seed.
    pub seed: u64,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        Self {
            gpus: 128,
            skew: 1.2,
            replicas: 2,
            seed: 42,
        }
    }
}

impl ProfileConfig {
    /// Topology derived from the GPU count: a big switch below 16 GPUs, a
    /// two-tier fabric of 8-GPU racks (x4 oversubscribed uplinks) up to 127
    /// racks, and a three-tier fabric stacking 8-rack pods (x2 rack, x4 pod
    /// uplinks — the bench harness's 1024-GPU shape) from 128 racks up.
    pub fn topology(&self) -> Result<Topology, String> {
        let n = self.gpus;
        if n < 16 {
            return Ok(Topology::BigSwitch);
        }
        let racks = n / 8;
        if racks < 16 {
            return Topology::even_two_tier(n, racks, 4.0).map_err(|e| e.to_string());
        }
        let pods = racks / 8;
        Topology::even_tiered(n, &[racks, pods], &[2.0, 4.0]).map_err(|e| e.to_string())
    }
}

/// Aggregate timing of every span sharing one name.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    /// Span name (e.g. `planner.replicate`).
    pub name: String,
    /// Number of spans recorded under this name.
    pub count: u64,
    /// Summed duration (µs).
    pub total_us: u64,
    /// Longest single span (µs).
    pub max_us: u64,
}

/// Result of one [`run_profile`] run.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// The config that was profiled.
    pub config: ProfileConfig,
    /// Human-readable topology description.
    pub topology: String,
    /// Per-phase aggregates, hottest (largest `total_us`) first.
    pub phases: Vec<PhaseStat>,
    /// Scheduled all-to-all time of the planned deployment (ms).
    pub schedule_ms: f64,
    /// The tracer that recorded the run — export via
    /// [`Tracer::to_chrome_string`] / [`Tracer::to_jsonl`].
    pub tracer: Tracer,
}

/// Group `spans` by name into [`PhaseStat`]s, hottest first (ties broken by
/// name so the order is deterministic).
pub fn aggregate_phases(spans: &[Span]) -> Vec<PhaseStat> {
    let mut stats: Vec<PhaseStat> = Vec::new();
    for s in spans {
        match stats.iter_mut().find(|p| p.name == s.name) {
            Some(p) => {
                p.count += 1;
                p.total_us += s.dur_us;
                p.max_us = p.max_us.max(s.dur_us);
            }
            None => stats.push(PhaseStat {
                name: s.name.clone(),
                count: 1,
                total_us: s.dur_us,
                max_us: s.dur_us,
            }),
        }
    }
    stats.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.name.cmp(&b.name)));
    stats
}

fn fmt_us(us: u64) -> String {
    if us < 1_000 {
        format!("{us} µs")
    } else if us < 1_000_000 {
        format!("{:.2} ms", us as f64 / 1e3)
    } else {
        format!("{:.3} s", us as f64 / 1e6)
    }
}

impl ProfileReport {
    /// Render the per-phase breakdown as an aligned table. The `%` column is
    /// relative to the summed root spans (nested phases overlap their
    /// parents, so percentages do not add to 100).
    pub fn render_table(&self) -> String {
        let root_us: u64 = self
            .tracer
            .spans()
            .iter()
            .filter(|s| s.parent.is_none())
            .map(|s| s.dur_us)
            .sum();
        let mut out = String::new();
        out.push_str(&format!(
            "{:<32} {:>7} {:>12} {:>12} {:>7}\n",
            "phase", "calls", "total", "max", "%"
        ));
        out.push_str(&"-".repeat(75));
        out.push('\n');
        for p in &self.phases {
            let pct = if root_us > 0 {
                100.0 * p.total_us as f64 / root_us as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<32} {:>7} {:>12} {:>12} {:>6.1}%\n",
                p.name,
                p.count,
                fmt_us(p.total_us),
                fmt_us(p.max_us),
                pct
            ));
        }
        out
    }
}

/// Plan (and, with `replicas ≥ 2`, replicate) a synthetic Zipf workload on
/// the derived topology, schedule the planned deployment's all-to-all, and
/// aggregate the recorded spans into a [`ProfileReport`].
pub fn run_profile(config: &ProfileConfig) -> Result<ProfileReport, String> {
    if config.gpus < 2 {
        return Err("profile needs at least 2 GPUs".into());
    }
    let tr = Tracer::wall();
    let cluster = Cluster::homogeneous(config.gpus, PROFILE_BW);
    let topo = config.topology()?;
    let trace: ModelTrace = skewed_workload(config.gpus, 2, 512, config.skew, config.seed);
    let refs = [&trace];
    let planner = Planner::default();

    // Plan — the replicated path re-plans the base deployment internally, so
    // one call traces placement, refinement, and (if enabled) replication.
    let agg = if config.replicas >= 2 {
        let rep_cfg = ReplicationConfig {
            max_replicas: config.replicas,
            ..ReplicationConfig::default()
        };
        let (rep, splits) = planner
            .plan_replicated_topology_traced(&refs, &cluster, &topo, &rep_cfg, &tr)
            .map_err(|e| e.to_string())?;
        rep.aggregated_traffic_split(&[&trace.layers[0]], &splits)
    } else {
        let dep = planner
            .plan_topology_traced(&refs, &cluster, &topo, &tr)
            .map_err(|e| e.to_string())?;
        dep.aggregated_traffic(&[&trace.layers[0]])
    };

    // Schedule the planned placement's all-to-all.
    let schedule_ms = match &topo {
        Topology::BigSwitch => {
            let sched = aurora_schedule_traced(&agg, &tr);
            sched.makespan_tokens() as f64 / PROFILE_BW
        }
        _ => {
            hierarchical_schedule_traced(&agg, &cluster, &topo, &tr)
                .map_err(|e| e.to_string())?
                .pipelined_ms
        }
    };

    let topology = match &topo {
        Topology::BigSwitch => "big switch".to_string(),
        Topology::TwoTier {
            groups,
            oversubscription,
        } => format!(
            "two-tier, {} groups, x{:.1} uplinks",
            groups.len(),
            oversubscription
        ),
        Topology::Tiered { levels } => {
            let desc: Vec<String> = levels
                .iter()
                .map(|lv| format!("{} groups x{:.1}", lv.groups.len(), lv.oversubscription))
                .collect();
            format!("{}-level tiered ({})", levels.len(), desc.join(", "))
        }
    };
    let phases = aggregate_phases(&tr.spans());
    Ok(ProfileReport {
        config: config.clone(),
        topology,
        phases,
        schedule_ms,
        tracer: tr,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::parse_chrome_trace;

    #[test]
    fn topology_derivation_tracks_the_gpu_count() {
        let shape = |gpus: usize| ProfileConfig {
            gpus,
            ..ProfileConfig::default()
        };
        assert!(matches!(shape(8).topology().unwrap(), Topology::BigSwitch));
        assert!(matches!(
            shape(64).topology().unwrap(),
            Topology::TwoTier { .. }
        ));
        assert!(matches!(
            shape(128).topology().unwrap(),
            Topology::Tiered { .. }
        ));
    }

    #[test]
    fn small_profile_run_produces_phases_and_a_parsable_trace() {
        let cfg = ProfileConfig {
            gpus: 16,
            ..ProfileConfig::default()
        };
        let report = run_profile(&cfg).unwrap();
        assert!(report.schedule_ms > 0.0);
        assert!(!report.phases.is_empty());
        // replication was on, so its phase must appear
        assert!(report.phases.iter().any(|p| p.name == "planner.replicate"));
        let table = report.render_table();
        assert!(table.contains("planner.replicate"), "{table}");
        // the recorded trace round-trips through the Chrome export
        let parsed = parse_chrome_trace(&report.tracer.to_chrome_string()).unwrap();
        assert_eq!(parsed.len(), report.tracer.spans().len());
    }

    #[test]
    fn replicas_1_skips_the_replication_pass() {
        let cfg = ProfileConfig {
            gpus: 16,
            replicas: 1,
            ..ProfileConfig::default()
        };
        let report = run_profile(&cfg).unwrap();
        assert!(report.phases.iter().all(|p| p.name != "planner.replicate"));
        assert!(report
            .phases
            .iter()
            .any(|p| p.name == "planner.plan_topology"));
    }

    #[test]
    fn aggregation_sums_counts_and_keeps_the_hottest_first() {
        let tr = Tracer::sim();
        let a = tr.begin("a");
        tr.set_sim_time_us(10);
        tr.end(a);
        let b = tr.begin("b");
        tr.set_sim_time_us(40);
        tr.end(b);
        let a2 = tr.begin("a");
        tr.set_sim_time_us(45);
        tr.end(a2);
        let phases = aggregate_phases(&tr.spans());
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].name, "b");
        assert_eq!(phases[0].total_us, 30);
        assert_eq!(phases[1].name, "a");
        assert_eq!(phases[1].count, 2);
        assert_eq!(phases[1].total_us, 15);
        assert_eq!(phases[1].max_us, 10);
    }
}
