//! Metrics registry: counters, gauges, and log-bucketed histograms.
//!
//! The [`MetricsRegistry`] is the single sink every subsystem reports
//! through — the serving simulator records per-window latency, per-GPU
//! utilization, and queue depth; `bench` records per-iteration timings; the
//! CLI snapshots the whole registry to JSON via
//! [`MetricsRegistry::snapshot`]. Like [`super::Tracer`], the registry is a
//! cheap-to-clone handle and [`MetricsRegistry::disabled`] is a total no-op,
//! so instrumentation can stay in place on hot paths.
//!
//! [`Histogram`] uses 64 power-of-two buckets (values `< 1` land in bucket
//! 0, value `v` in bucket `1 + floor(log2 v)`, capped at 63), giving
//! ≤ 2× relative quantile error over the full `f64` range with a fixed
//! 64-slot footprint. Non-finite samples are **counted and dropped**, never
//! stored — the registry cannot be poisoned by a NaN.
//!
//! This module also owns the exact-percentile helpers ([`percentile`],
//! [`p50_p95_p99`]) that `serve::metrics` re-exports: they return typed
//! [`MetricsError`]s instead of panicking, and filter non-finite samples
//! with a count rather than asserting them away.

use crate::util::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt;
use std::rc::Rc;

/// Typed errors for percentile/summary queries.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricsError {
    /// `p` was outside `[0, 1]` (or not finite).
    InvalidPercentile { p: f64 },
    /// Every sample was NaN/±∞ (or the slice was empty); `dropped` counts
    /// the non-finite samples that were filtered out.
    NoFiniteSamples { dropped: usize },
}

impl fmt::Display for MetricsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MetricsError::InvalidPercentile { p } => {
                write!(f, "percentile p={p} is outside [0, 1]")
            }
            MetricsError::NoFiniteSamples { dropped } => {
                write!(f, "no finite samples ({dropped} non-finite dropped)")
            }
        }
    }
}

impl std::error::Error for MetricsError {}

/// Exact percentile (nearest-rank) over the finite samples of `xs`.
///
/// Non-finite samples are filtered (their count is reported through
/// [`MetricsError::NoFiniteSamples`] when nothing survives); out-of-range
/// `p` is a typed error, not a panic. `p = 0` is the minimum, `p = 1` the
/// maximum.
pub fn percentile(xs: &[f64], p: f64) -> Result<f64, MetricsError> {
    if !(0.0..=1.0).contains(&p) {
        return Err(MetricsError::InvalidPercentile { p });
    }
    let mut finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if finite.is_empty() {
        return Err(MetricsError::NoFiniteSamples {
            dropped: xs.len() - finite.len(),
        });
    }
    finite.sort_by(f64::total_cmp);
    let idx = ((finite.len() as f64 - 1.0) * p).round() as usize;
    Ok(finite[idx.min(finite.len() - 1)])
}

/// `(p50, p95, p99)` of the finite samples of `xs` in one pass.
pub fn p50_p95_p99(xs: &[f64]) -> Result<(f64, f64, f64), MetricsError> {
    let mut finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if finite.is_empty() {
        return Err(MetricsError::NoFiniteSamples {
            dropped: xs.len() - finite.len(),
        });
    }
    finite.sort_by(f64::total_cmp);
    let pick = |p: f64| {
        let idx = ((finite.len() as f64 - 1.0) * p).round() as usize;
        finite[idx.min(finite.len() - 1)]
    };
    Ok((pick(0.50), pick(0.95), pick(0.99)))
}

const BUCKETS: usize = 64;

/// Log-bucketed histogram over non-negative `f64` samples.
///
/// Fixed 64-bucket footprint, ≤ 2× relative quantile error; exact
/// count/sum/min/max are tracked alongside the buckets. Non-finite (or
/// negative) samples are dropped and counted in [`Histogram::dropped`].
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    dropped: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            dropped: 0,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v < 1.0 {
            0
        } else {
            (1 + v.log2().floor() as usize).min(BUCKETS - 1)
        }
    }

    /// Upper edge of bucket `i` (the quantile estimate reported for it).
    fn bucket_upper(i: usize) -> f64 {
        if i == 0 {
            1.0
        } else {
            (2u64 << (i - 1).min(62)) as f64 // 2^i
        }
    }

    /// Record one sample. NaN, ±∞, and negative values are dropped (and
    /// counted), keeping the histogram well-defined under adversarial input.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            self.dropped += 1;
            return;
        }
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Number of non-finite/negative samples rejected by [`Histogram::record`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate quantile: walks the cumulative bucket counts and reports
    /// the matched bucket's upper edge, clamped to the exact observed
    /// min/max (so `q(0)` and `q(1)` are exact).
    pub fn quantile(&self, q: f64) -> Result<f64, MetricsError> {
        if !(0.0..=1.0).contains(&q) {
            return Err(MetricsError::InvalidPercentile { p: q });
        }
        if self.count == 0 {
            return Err(MetricsError::NoFiniteSamples {
                dropped: self.dropped as usize,
            });
        }
        let rank = (q * (self.count as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return Ok(Self::bucket_upper(i).clamp(self.min, self.max));
            }
        }
        Ok(self.max)
    }

    /// JSON form: exact aggregates plus the sparse nonzero buckets as
    /// `[index, count]` pairs.
    pub fn to_json(&self) -> Json {
        let nonzero: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::from(i), Json::from(c)]))
            .collect();
        Json::obj(vec![
            ("count", Json::from(self.count)),
            ("dropped", Json::from(self.dropped)),
            ("sum", Json::from(self.sum)),
            ("mean", Json::from(self.mean())),
            ("min", Json::from(self.min())),
            ("max", Json::from(self.max())),
            ("p50", Json::from(self.quantile(0.50).unwrap_or(0.0))),
            ("p90", Json::from(self.quantile(0.90).unwrap_or(0.0))),
            ("p99", Json::from(self.quantile(0.99).unwrap_or(0.0))),
            ("buckets", Json::Arr(nonzero)),
        ])
    }
}

#[derive(Debug, Default)]
struct RegInner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Cheap-to-clone metrics handle (clones share the underlying store);
/// [`MetricsRegistry::disabled`] records nothing.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry(Option<Rc<RefCell<RegInner>>>);

impl MetricsRegistry {
    /// The no-op registry.
    pub fn disabled() -> MetricsRegistry {
        MetricsRegistry(None)
    }

    /// A live, empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry(Some(Rc::new(RefCell::new(RegInner::default()))))
    }

    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Add `delta` to a monotonic counter (created at 0 on first touch).
    pub fn counter_add(&self, name: &str, delta: u64) {
        if let Some(inner) = &self.0 {
            *inner.borrow_mut().counters.entry(name.to_string()).or_insert(0) += delta;
        }
    }

    /// Set a gauge to its latest value.
    pub fn gauge_set(&self, name: &str, value: f64) {
        if let Some(inner) = &self.0 {
            inner.borrow_mut().gauges.insert(name.to_string(), value);
        }
    }

    /// Record one sample into a named histogram (created empty on first
    /// touch; non-finite samples are dropped-and-counted, see
    /// [`Histogram::record`]).
    pub fn hist_record(&self, name: &str, value: f64) {
        if let Some(inner) = &self.0 {
            inner
                .borrow_mut()
                .histograms
                .entry(name.to_string())
                .or_default()
                .record(value);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        match &self.0 {
            Some(inner) => inner.borrow().counters.get(name).copied().unwrap_or(0),
            None => 0,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.0.as_ref().and_then(|inner| inner.borrow().gauges.get(name).copied())
    }

    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.0.as_ref().and_then(|inner| inner.borrow().histograms.get(name).cloned())
    }

    /// Full JSON snapshot:
    /// `{"counters":{..},"gauges":{..},"histograms":{name:{count,..,buckets}}}`.
    /// Deterministic (names are sorted) so snapshots diff cleanly.
    pub fn snapshot(&self) -> Json {
        let Some(inner) = &self.0 else {
            return Json::obj(vec![
                ("counters", Json::obj(vec![])),
                ("gauges", Json::obj(vec![])),
                ("histograms", Json::obj(vec![])),
            ]);
        };
        let inner = inner.borrow();
        let counters = inner
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), Json::from(v)))
            .collect::<BTreeMap<_, _>>();
        let gauges = inner
            .gauges
            .iter()
            .map(|(k, &v)| (k.clone(), Json::from(v)))
            .collect::<BTreeMap<_, _>>();
        let hists = inner
            .histograms
            .iter()
            .map(|(k, h)| (k.clone(), h.to_json()))
            .collect::<BTreeMap<_, _>>();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_typed_errors() {
        assert_eq!(
            percentile(&[1.0], 1.5),
            Err(MetricsError::InvalidPercentile { p: 1.5 })
        );
        assert_eq!(
            percentile(&[1.0], -0.1),
            Err(MetricsError::InvalidPercentile { p: -0.1 })
        );
        assert_eq!(percentile(&[], 0.5), Err(MetricsError::NoFiniteSamples { dropped: 0 }));
    }

    #[test]
    fn percentile_filters_non_finite() {
        let xs = [f64::NAN, 3.0, f64::INFINITY, 1.0, f64::NEG_INFINITY, 2.0];
        assert_eq!(percentile(&xs, 0.0), Ok(1.0));
        assert_eq!(percentile(&xs, 0.5), Ok(2.0));
        assert_eq!(percentile(&xs, 1.0), Ok(3.0));
    }

    #[test]
    fn percentile_all_non_finite_reports_drop_count() {
        let xs = [f64::NAN, f64::INFINITY, f64::NAN];
        assert_eq!(
            percentile(&xs, 0.5),
            Err(MetricsError::NoFiniteSamples { dropped: 3 })
        );
        assert_eq!(
            p50_p95_p99(&xs),
            Err(MetricsError::NoFiniteSamples { dropped: 3 })
        );
    }

    #[test]
    fn p50_p95_p99_on_known_data() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let (p50, p95, p99) = p50_p95_p99(&xs).unwrap();
        assert_eq!(p50, 50.0);
        assert_eq!(p95, 95.0);
        assert_eq!(p99, 99.0);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::new();
        for v in [0.5, 1.0, 2.0, 3.0, 100.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.quantile(0.0).unwrap(), 0.5);
        assert_eq!(h.quantile(1.0).unwrap(), 100.0);
        let p50 = h.quantile(0.5).unwrap();
        // exact median is 2.0; log buckets may report up to its bucket edge (4)
        assert!((2.0..=4.0).contains(&p50), "p50 = {p50}");
    }

    #[test]
    fn histogram_drops_adversarial_samples() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(-1.0);
        h.record(2.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.dropped(), 4);
        assert_eq!(h.quantile(0.5), Ok(2.0));
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.5), Err(MetricsError::NoFiniteSamples { dropped: 0 }));
    }

    #[test]
    fn histogram_huge_values_stay_in_range() {
        let mut h = Histogram::new();
        h.record(1e300);
        h.record(f64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.5).unwrap().is_finite());
    }

    #[test]
    fn registry_counters_gauges_hists() {
        let m = MetricsRegistry::new();
        m.counter_add("windows", 2);
        m.counter_add("windows", 3);
        m.gauge_set("util", 0.75);
        m.hist_record("latency", 10.0);
        m.hist_record("latency", 20.0);
        assert_eq!(m.counter("windows"), 5);
        assert_eq!(m.gauge("util"), Some(0.75));
        assert_eq!(m.histogram("latency").unwrap().count(), 2);
        let snap = m.snapshot();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("windows")).and_then(|v| v.as_u64()),
            Some(5)
        );
        assert!(snap.get("histograms").and_then(|h| h.get("latency")).is_some());
    }

    #[test]
    fn disabled_registry_is_inert() {
        let m = MetricsRegistry::disabled();
        m.counter_add("x", 1);
        m.gauge_set("g", 1.0);
        m.hist_record("h", 1.0);
        assert_eq!(m.counter("x"), 0);
        assert_eq!(m.gauge("g"), None);
        assert!(m.histogram("h").is_none());
        assert!(!m.is_enabled());
    }

    #[test]
    fn clones_share_the_store() {
        let m = MetricsRegistry::new();
        let c = m.clone();
        c.counter_add("n", 7);
        assert_eq!(m.counter("n"), 7);
    }
}
