//! GPU/link time attribution: typed timelines behind every simulator.
//!
//! Every simulator in [`crate::sim`] reduces a layer (or a window) to a
//! makespan plus one utilization scalar. This module keeps the *shape* of
//! that time: a [`TimelineRecorder`] threaded through the closed-form and
//! event simulators collects typed, non-overlapping [`Segment`]s per GPU
//! compute engine and per (up/down) access link, so every GPU-millisecond of
//! a simulated layer is attributed to exactly one cause:
//!
//! * [`SegmentKind::Compute`] — the engine runs gate/FFN/aggregation for one
//!   model;
//! * [`SegmentKind::SyncWait`] — the engine is idle *between* tasks, blocked
//!   on an all-to-all barrier (data not yet delivered);
//! * [`SegmentKind::Idle`] — the trailing gap after the engine's last task;
//! * [`SegmentKind::CommSend`] / [`SegmentKind::CommRecv`] — the GPU's
//!   uplink/downlink drains dispatch or combine traffic (lower-bound
//!   attribution: per-link bytes over per-link bandwidth, placed inside the
//!   phase window the simulator derived);
//! * [`SegmentKind::SwapDrain`] — link time spent on migration/staging
//!   background traffic ([`crate::sim::simulate_window`]'s extra model).
//!
//! The recorder mirrors the [`Tracer`] contract: [`TimelineRecorder::disabled`]
//! is a total no-op, recording is purely observational, and an integration
//! test pins that simulator results are bit-for-bit identical with recording
//! on or off. Engine timelines exactly partition `[0, makespan]` (idle
//! included) — a property test enforces it — so [`Timelines::utilization`]
//! reproduces the simulators' legacy utilization scalar from first
//! principles, and [`Timelines::breakdown`] splits the makespan per kind,
//! per GPU and cluster-wide. [`Timelines::to_tracer`] exports the whole
//! thing as a multi-track Chrome trace (engine, uplink, and downlink lanes
//! per GPU) through the existing [`Tracer`] plumbing.

use crate::obs::tracer::Tracer;
use crate::schedule::SlotSchedule;
use crate::traffic::TrafficMatrix;
use std::fmt::Write as _;

/// What a GPU engine or access link was doing during one segment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SegmentKind {
    /// Engine busy computing (gate/FFN/aggregation) for model `model`.
    Compute {
        /// Index of the model in the simulated group.
        model: usize,
    },
    /// Uplink busy transmitting dispatch/combine traffic.
    CommSend,
    /// Downlink busy receiving dispatch/combine traffic.
    CommRecv,
    /// Engine idle, blocked on an all-to-all barrier.
    SyncWait,
    /// Link busy draining migration/staging background traffic.
    SwapDrain,
    /// Trailing engine idle after the GPU's last task of the layer.
    Idle,
}

impl SegmentKind {
    /// Stable snake_case name (Chrome-trace label, table headers).
    pub fn name(&self) -> &'static str {
        match self {
            SegmentKind::Compute { .. } => "compute",
            SegmentKind::CommSend => "comm_send",
            SegmentKind::CommRecv => "comm_recv",
            SegmentKind::SyncWait => "sync_wait",
            SegmentKind::SwapDrain => "swap_drain",
            SegmentKind::Idle => "idle",
        }
    }
}

/// One attributed time interval on an engine or link.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Interval start (ms, layer-relative).
    pub start_ms: f64,
    /// Interval end (ms).
    pub end_ms: f64,
    /// Attribution.
    pub kind: SegmentKind,
}

impl Segment {
    /// Interval length (ms).
    pub fn dur_ms(&self) -> f64 {
        self.end_ms - self.start_ms
    }
}

/// One GPU compute engine's attributed timeline: sorted, non-overlapping
/// segments exactly partitioning `[0, makespan]`.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuTimeline {
    /// GPU index.
    pub gpu: usize,
    /// Segments in time order.
    pub segments: Vec<Segment>,
}

/// One access link's busy intervals (uplink or downlink of one GPU): sorted
/// and non-overlapping, but *not* a partition — links are otherwise idle.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkTimeline {
    /// GPU index the link belongs to.
    pub gpu: usize,
    /// Busy segments in time order.
    pub segments: Vec<Segment>,
}

impl GpuTimeline {
    /// Total engine-busy (compute) time (ms).
    pub fn compute_ms(&self) -> f64 {
        self.segments
            .iter()
            .filter(|s| matches!(s.kind, SegmentKind::Compute { .. }))
            .map(Segment::dur_ms)
            .sum()
    }
}

impl LinkTimeline {
    /// Total link-busy time (ms), all kinds.
    pub fn busy_ms(&self) -> f64 {
        self.segments.iter().map(Segment::dur_ms).sum()
    }
}

/// Fractions of the makespan per segment kind for one GPU (or, averaged,
/// for the cluster). Engine fractions (`compute` + `sync_wait` + `idle`)
/// sum to 1; link fractions are busy shares of the same makespan.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct KindShare {
    /// Engine computing.
    pub compute: f64,
    /// Engine blocked on an all-to-all barrier.
    pub sync_wait: f64,
    /// Engine idle after its last task.
    pub idle: f64,
    /// Uplink busy sending dispatch/combine traffic.
    pub comm_send: f64,
    /// Downlink busy receiving dispatch/combine traffic.
    pub comm_recv: f64,
    /// Up+down link time on migration/staging background traffic.
    pub swap_drain: f64,
}

/// Per-GPU and cluster-aggregate makespan attribution.
#[derive(Debug, Clone, PartialEq)]
pub struct Breakdown {
    /// Layer/window makespan (ms).
    pub makespan_ms: f64,
    /// One entry per GPU.
    pub per_gpu: Vec<KindShare>,
    /// Mean of `per_gpu` — the cluster-wide split.
    pub cluster: KindShare,
}

/// Per-link occupancy of one schedule round: what fraction of the round's
/// per-port token budget each GPU's uplink/downlink actually carries.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundOccupancy {
    /// Which all-to-all the round belongs to (`"N"` dispatch, `"C"` combine).
    pub phase: String,
    /// Round index within the phase's slot schedule.
    pub round: usize,
    /// Round length in tokens (per-port budget).
    pub duration_tokens: u64,
    /// Per-GPU uplink busy fraction of the round (`real_tokens / duration`).
    pub up: Vec<f64>,
    /// Per-GPU downlink busy fraction of the round.
    pub down: Vec<f64>,
}

/// Per-round, per-link occupancy of one [`SlotSchedule`] (one all-to-all).
pub fn schedule_round_occupancy(s: &SlotSchedule, phase: &str) -> Vec<RoundOccupancy> {
    s.rounds
        .iter()
        .enumerate()
        .map(|(r, round)| {
            let mut up = vec![0.0; s.n];
            let mut down = vec![0.0; s.n];
            let d = round.duration.max(1) as f64;
            for &(src, dst, real) in &round.transfers {
                up[src] += real as f64 / d;
                down[dst] += real as f64 / d;
            }
            RoundOccupancy {
                phase: phase.to_string(),
                round: r,
                duration_tokens: round.duration,
                up,
                down,
            }
        })
        .collect()
}

/// The one utilization definition shared by every simulator and the
/// timeline view: mean per-GPU busy fraction, `Σ busy / (n · makespan)`.
/// Returns 0 for an empty cluster or a non-positive/non-finite makespan.
pub fn mean_busy_fraction(busy_ms: &[f64], makespan_ms: f64) -> f64 {
    if busy_ms.is_empty() || !(makespan_ms > 0.0) {
        return 0.0;
    }
    busy_ms.iter().sum::<f64>() / busy_ms.len() as f64 / makespan_ms
}

/// A complete recorded layer/window: engine + link timelines, makespan, and
/// (when the Aurora policy ran) per-round link occupancy.
#[derive(Debug, Clone, PartialEq)]
pub struct Timelines {
    /// Layer/window makespan (ms).
    pub makespan_ms: f64,
    /// Engine timelines, one per GPU, each partitioning `[0, makespan]`.
    pub gpus: Vec<GpuTimeline>,
    /// Uplink busy timelines, one per GPU.
    pub uplinks: Vec<LinkTimeline>,
    /// Downlink busy timelines, one per GPU.
    pub downlinks: Vec<LinkTimeline>,
    /// Per-round link occupancy of the aggregate dispatch/combine schedules
    /// (Aurora policy only; empty for baseline policies).
    pub rounds: Vec<RoundOccupancy>,
}

impl Timelines {
    /// Per-GPU total compute time (ms) — the timeline view of the
    /// simulators' `per_gpu_compute_ms` / `busy` vectors.
    pub fn per_gpu_compute_ms(&self) -> Vec<f64> {
        self.gpus.iter().map(GpuTimeline::compute_ms).collect()
    }

    /// Per-GPU total link busy time (ms): uplink + downlink occupancy of the
    /// GPU's full-duplex port. Busy time is volume / port rate, independent
    /// of scheduling order — the bandwidth-side signal
    /// [`crate::obs::degrade::DegradationDetector`] ratios against the
    /// plan-time prediction.
    pub fn per_gpu_link_busy_ms(&self) -> Vec<f64> {
        (0..self.gpus.len())
            .map(|g| self.uplinks[g].busy_ms() + self.downlinks[g].busy_ms())
            .collect()
    }

    /// Cluster utilization derived from the timeline; matches the legacy
    /// simulator scalar (pinned by a property test).
    pub fn utilization(&self) -> f64 {
        mean_busy_fraction(&self.per_gpu_compute_ms(), self.makespan_ms)
    }

    /// Fraction of the makespan per segment kind, per GPU and cluster-wide.
    pub fn breakdown(&self) -> Breakdown {
        let n = self.gpus.len();
        let span = self.makespan_ms;
        let frac = |ms: f64| if span > 0.0 { ms / span } else { 0.0 };
        let mut per_gpu = Vec::with_capacity(n);
        for g in 0..n {
            let mut share = KindShare::default();
            for s in &self.gpus[g].segments {
                match s.kind {
                    SegmentKind::Compute { .. } => share.compute += frac(s.dur_ms()),
                    SegmentKind::SyncWait => share.sync_wait += frac(s.dur_ms()),
                    SegmentKind::Idle => share.idle += frac(s.dur_ms()),
                    _ => {}
                }
            }
            for s in &self.uplinks[g].segments {
                match s.kind {
                    SegmentKind::SwapDrain => share.swap_drain += frac(s.dur_ms()),
                    _ => share.comm_send += frac(s.dur_ms()),
                }
            }
            for s in &self.downlinks[g].segments {
                match s.kind {
                    SegmentKind::SwapDrain => share.swap_drain += frac(s.dur_ms()),
                    _ => share.comm_recv += frac(s.dur_ms()),
                }
            }
            per_gpu.push(share);
        }
        let mut cluster = KindShare::default();
        if n > 0 {
            for s in &per_gpu {
                cluster.compute += s.compute;
                cluster.sync_wait += s.sync_wait;
                cluster.idle += s.idle;
                cluster.comm_send += s.comm_send;
                cluster.comm_recv += s.comm_recv;
                cluster.swap_drain += s.swap_drain;
            }
            let inv = 1.0 / n as f64;
            cluster.compute *= inv;
            cluster.sync_wait *= inv;
            cluster.idle *= inv;
            cluster.comm_send *= inv;
            cluster.comm_recv *= inv;
            cluster.swap_drain *= inv;
        }
        Breakdown {
            makespan_ms: span,
            per_gpu,
            cluster,
        }
    }

    /// Rendered per-GPU breakdown table (percent of makespan per kind).
    pub fn render_table(&self) -> String {
        let b = self.breakdown();
        let mut out = String::new();
        let _ = writeln!(
            out,
            "GPU-millisecond attribution (makespan {:.3} ms)",
            b.makespan_ms
        );
        let _ = writeln!(
            out,
            "{:>5} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10}",
            "gpu", "compute%", "sync%", "idle%", "up-busy%", "dn-busy%", "swap%"
        );
        let mut row = |label: &str, s: &KindShare| {
            let _ = writeln!(
                out,
                "{label:>5} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>10.1}",
                100.0 * s.compute,
                100.0 * s.sync_wait,
                100.0 * s.idle,
                100.0 * s.comm_send,
                100.0 * s.comm_recv,
                100.0 * s.swap_drain,
            );
        };
        for (g, s) in b.per_gpu.iter().enumerate() {
            row(&g.to_string(), s);
        }
        row("all", &b.cluster);
        out
    }

    /// Export as a multi-track Chrome trace through the [`Tracer`]: engine
    /// segments on track `gpu`, uplinks on `n + gpu`, downlinks on
    /// `2n + gpu`, each span labelled with its segment kind.
    pub fn to_tracer(&self) -> Tracer {
        let tr = Tracer::sim();
        let n = self.gpus.len() as u32;
        let us = |ms: f64| (ms * 1e3).round().max(0.0) as u64;
        let mut emit = |track: u32, lane: &str, gpu: usize, segs: &[Segment]| {
            tr.set_track(track);
            for s in segs {
                let (a, b) = (us(s.start_ms), us(s.end_ms));
                if b <= a {
                    continue; // sub-microsecond segment: invisible at trace resolution
                }
                tr.set_sim_time_us(a);
                let sp = tr.begin(&format!("timeline.{}", s.kind.name()));
                tr.label(sp, "kind", s.kind.name());
                tr.label(sp, "lane", lane);
                tr.counter(sp, "gpu", gpu as i64);
                if let SegmentKind::Compute { model } = s.kind {
                    tr.counter(sp, "model", model as i64);
                }
                tr.set_sim_time_us(b);
                tr.end(sp);
            }
        };
        for (g, t) in self.gpus.iter().enumerate() {
            emit(g as u32, "engine", g, &t.segments);
        }
        for (g, t) in self.uplinks.iter().enumerate() {
            emit(n + g as u32, "uplink", g, &t.segments);
        }
        for (g, t) in self.downlinks.iter().enumerate() {
            emit(2 * n + g as u32, "downlink", g, &t.segments);
        }
        tr
    }

    /// Chrome trace-event JSON of [`Timelines::to_tracer`].
    pub fn to_chrome_string(&self) -> String {
        self.to_tracer().to_chrome_string()
    }
}

struct RecorderInner {
    n: usize,
    compute: Vec<Vec<Segment>>,
    up: Vec<Vec<Segment>>,
    down: Vec<Vec<Segment>>,
    up_cursor: Vec<f64>,
    down_cursor: Vec<f64>,
    swap_model: Option<usize>,
    rounds: Vec<RoundOccupancy>,
    makespan_ms: f64,
}

/// Collects segments from a simulator run. [`TimelineRecorder::disabled`] is
/// a total no-op (mirroring [`Tracer::disabled`]); recording never feeds
/// back into simulator arithmetic, so results are bit-for-bit identical
/// with the recorder on or off.
pub struct TimelineRecorder {
    inner: Option<RecorderInner>,
}

impl TimelineRecorder {
    /// No-op recorder: every `record_*` call returns immediately.
    pub fn disabled() -> Self {
        Self { inner: None }
    }

    /// Recorder for an `n_gpus` cluster.
    pub fn new(n_gpus: usize) -> Self {
        Self {
            inner: Some(RecorderInner {
                n: n_gpus,
                compute: vec![Vec::new(); n_gpus],
                up: vec![Vec::new(); n_gpus],
                down: vec![Vec::new(); n_gpus],
                up_cursor: vec![0.0; n_gpus],
                down_cursor: vec![0.0; n_gpus],
                swap_model: None,
                rounds: Vec::new(),
                makespan_ms: 0.0,
            }),
        }
    }

    /// Whether the recorder collects anything. Simulators may use this to
    /// skip observational-only work (e.g. deriving slot schedules for
    /// per-round occupancy).
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Mark one model index as migration/staging background traffic: its
    /// link segments are recorded as [`SegmentKind::SwapDrain`].
    pub fn set_swap_drain_model(&mut self, model: usize) {
        if let Some(inner) = &mut self.inner {
            inner.swap_model = Some(model);
        }
    }

    /// Record one engine-busy interval on GPU `gpu` for `model`.
    pub fn record_compute(&mut self, gpu: usize, model: usize, start_ms: f64, end_ms: f64) {
        if let Some(inner) = &mut self.inner {
            if end_ms > start_ms {
                inner.compute[gpu].push(Segment {
                    start_ms,
                    end_ms,
                    kind: SegmentKind::Compute { model },
                });
            }
        }
    }

    /// Record one all-to-all of `model` occupying the window
    /// `[window_start, window_end]`: each GPU's uplink carries its row sum
    /// and its downlink its column sum of `d`, at that GPU's bandwidth —
    /// the per-link lower bound, placed at the earliest free instant inside
    /// the window. Phases must be recorded in chronological order.
    pub fn record_comm(
        &mut self,
        model: usize,
        window_start: f64,
        window_end: f64,
        d: &TrafficMatrix,
        bandwidths: &[f64],
    ) {
        let Some(inner) = &mut self.inner else {
            return;
        };
        let _ = window_end;
        let swap = inner.swap_model == Some(model);
        for g in 0..inner.n {
            let send_ms = d.row_sum(g) as f64 / bandwidths[g];
            if send_ms > 0.0 {
                let start = window_start.max(inner.up_cursor[g]);
                let end = start + send_ms;
                inner.up[g].push(Segment {
                    start_ms: start,
                    end_ms: end,
                    kind: if swap {
                        SegmentKind::SwapDrain
                    } else {
                        SegmentKind::CommSend
                    },
                });
                inner.up_cursor[g] = end;
            }
            let recv_ms = d.col_sum(g) as f64 / bandwidths[g];
            if recv_ms > 0.0 {
                let start = window_start.max(inner.down_cursor[g]);
                let end = start + recv_ms;
                inner.down[g].push(Segment {
                    start_ms: start,
                    end_ms: end,
                    kind: if swap {
                        SegmentKind::SwapDrain
                    } else {
                        SegmentKind::CommRecv
                    },
                });
                inner.down_cursor[g] = end;
            }
        }
    }

    /// Record per-round link occupancy of one phase's slot schedule.
    pub fn record_rounds(&mut self, phase: &str, schedule: &SlotSchedule) {
        if let Some(inner) = &mut self.inner {
            inner
                .rounds
                .extend(schedule_round_occupancy(schedule, phase));
        }
    }

    /// Set the layer/window makespan the engine timelines partition.
    pub fn set_makespan(&mut self, makespan_ms: f64) {
        if let Some(inner) = &mut self.inner {
            inner.makespan_ms = makespan_ms;
        }
    }

    /// Consume the recording into [`Timelines`]: engine gaps between tasks
    /// become [`SegmentKind::SyncWait`], the trailing gap [`SegmentKind::Idle`].
    /// Returns `None` for a disabled recorder.
    pub fn take(&mut self) -> Option<Timelines> {
        let inner = self.inner.take()?;
        let span = inner.makespan_ms;
        let mut gpus = Vec::with_capacity(inner.n);
        for (g, mut segs) in inner.compute.into_iter().enumerate() {
            segs.sort_by(|a, b| a.start_ms.total_cmp(&b.start_ms));
            let mut full = Vec::with_capacity(segs.len() * 2 + 1);
            let mut t = 0.0f64;
            for s in segs {
                // guard float noise: engine serialization guarantees s.start >= t
                let start = s.start_ms.max(t);
                let end = s.end_ms.max(start);
                if start > t {
                    full.push(Segment {
                        start_ms: t,
                        end_ms: start,
                        kind: SegmentKind::SyncWait,
                    });
                }
                full.push(Segment {
                    start_ms: start,
                    end_ms: end,
                    kind: s.kind,
                });
                t = end;
            }
            if span > t {
                full.push(Segment {
                    start_ms: t,
                    end_ms: span,
                    kind: SegmentKind::Idle,
                });
            }
            gpus.push(GpuTimeline {
                gpu: g,
                segments: full,
            });
        }
        let link = |v: Vec<Vec<Segment>>| {
            v.into_iter()
                .enumerate()
                .map(|(g, segments)| LinkTimeline { gpu: g, segments })
                .collect()
        };
        Some(Timelines {
            makespan_ms: span,
            gpus,
            uplinks: link(inner.up),
            downlinks: link(inner.down),
            rounds: inner.rounds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_noop() {
        let mut rec = TimelineRecorder::disabled();
        assert!(!rec.is_enabled());
        rec.record_compute(0, 0, 0.0, 1.0);
        rec.set_makespan(2.0);
        assert!(rec.take().is_none());
    }

    #[test]
    fn gaps_classified_sync_then_idle() {
        let mut rec = TimelineRecorder::new(1);
        rec.record_compute(0, 0, 1.0, 2.0);
        rec.record_compute(0, 0, 3.0, 4.0);
        rec.set_makespan(5.0);
        let tl = rec.take().unwrap();
        let kinds: Vec<&str> = tl.gpus[0].segments.iter().map(|s| s.kind.name()).collect();
        assert_eq!(
            kinds,
            vec!["sync_wait", "compute", "sync_wait", "compute", "idle"]
        );
        // exact partition of [0, makespan]
        let mut t = 0.0;
        for s in &tl.gpus[0].segments {
            assert_eq!(s.start_ms, t);
            t = s.end_ms;
        }
        assert_eq!(t, 5.0);
        assert!((tl.utilization() - 2.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn zero_length_compute_skipped() {
        let mut rec = TimelineRecorder::new(1);
        rec.record_compute(0, 0, 1.0, 1.0);
        rec.set_makespan(1.0);
        let tl = rec.take().unwrap();
        assert_eq!(tl.gpus[0].segments.len(), 1);
        assert_eq!(tl.gpus[0].segments[0].kind, SegmentKind::Idle);
        assert_eq!(tl.utilization(), 0.0);
    }

    #[test]
    fn comm_attribution_uses_link_sums() {
        let d = TrafficMatrix::from_nested(&[vec![0, 4], vec![2, 0]]).unwrap();
        let mut rec = TimelineRecorder::new(2);
        rec.record_comm(0, 1.0, 10.0, &d, &[2.0, 2.0]);
        rec.set_makespan(10.0);
        let tl = rec.take().unwrap();
        // GPU0 sends 4 tokens at bw 2 -> 2ms from the window start
        assert_eq!(tl.uplinks[0].segments[0].start_ms, 1.0);
        assert_eq!(tl.uplinks[0].segments[0].end_ms, 3.0);
        assert_eq!(tl.uplinks[0].segments[0].kind, SegmentKind::CommSend);
        // GPU0 receives 2 tokens -> 1ms
        assert_eq!(tl.downlinks[0].segments[0].dur_ms(), 1.0);
        assert_eq!(tl.downlinks[0].segments[0].kind, SegmentKind::CommRecv);
    }

    #[test]
    fn swap_drain_model_marks_links() {
        let d = TrafficMatrix::from_nested(&[vec![0, 4], vec![2, 0]]).unwrap();
        let mut rec = TimelineRecorder::new(2);
        rec.set_swap_drain_model(1);
        rec.record_comm(1, 0.0, 5.0, &d, &[1.0, 1.0]);
        rec.set_makespan(5.0);
        let tl = rec.take().unwrap();
        assert_eq!(tl.uplinks[0].segments[0].kind, SegmentKind::SwapDrain);
        assert_eq!(tl.downlinks[1].segments[0].kind, SegmentKind::SwapDrain);
    }

    #[test]
    fn chrome_export_round_trips() {
        let mut rec = TimelineRecorder::new(2);
        rec.record_compute(0, 0, 0.0, 1.5);
        rec.record_compute(1, 1, 0.5, 2.0);
        rec.set_makespan(3.0);
        let tl = rec.take().unwrap();
        let text = tl.to_chrome_string();
        let spans = crate::obs::tracer::parse_chrome_trace(&text).unwrap();
        assert!(!spans.is_empty());
        // engine lanes 0/1, and every span carries a kind label
        for s in &spans {
            assert!(s.labels.iter().any(|(k, _)| k == "kind"), "{}", s.name);
        }
    }

    #[test]
    fn round_occupancy_fractions() {
        use crate::schedule::{SlotRound, SlotSchedule};
        let s = SlotSchedule {
            n: 2,
            rounds: vec![SlotRound {
                duration: 4,
                transfers: vec![(0, 1, 3)],
            }],
        };
        let occ = schedule_round_occupancy(&s, "N");
        assert_eq!(occ.len(), 1);
        assert_eq!(occ[0].up, vec![0.75, 0.0]);
        assert_eq!(occ[0].down, vec![0.0, 0.75]);
    }

    #[test]
    fn mean_busy_fraction_guards_degenerate_inputs() {
        assert_eq!(mean_busy_fraction(&[], 1.0), 0.0);
        assert_eq!(mean_busy_fraction(&[1.0], 0.0), 0.0);
        assert_eq!(mean_busy_fraction(&[1.0], f64::NAN), 0.0);
        assert_eq!(mean_busy_fraction(&[1.0, 3.0], 4.0), 0.5);
    }
}
