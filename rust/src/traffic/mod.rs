//! Traffic matrices for all-to-all communication.
//!
//! The token distribution of an MoE layer's all-to-all is an `n × n` matrix
//! `D` with `d_ij` = number of tokens GPU `i` sends to GPU `j` (paper §4,
//! Table 1). The two all-to-alls of one layer are *reversed*: `D_C = D_N^T`
//! (§2.2). Diagonal entries are local (no network) and are excluded from all
//! communication-time computations (paper footnote 1).

mod augment;
mod matrix;

pub use augment::{
    augment_to_balanced, drifting_zipf_traffic, flash_crowd_traffic, multiplicative_noise,
    sampled_zipf_traffic, zipf_traffic, zipf_weights,
};
pub use matrix::{split_tokens, NonzeroIter, TrafficError, TrafficMatrix};
