//! The [`TrafficMatrix`] type and its row/column/bound arithmetic.

use std::fmt;

/// An `n × n` all-to-all traffic matrix in integer tokens.
///
/// Entry `(i, j)` is the number of tokens GPU `i` sends to GPU `j`.
/// Diagonal entries represent tokens whose source and destination expert live
/// on the same GPU; they never touch the network and are ignored by every
/// communication-time computation (paper footnote 1, §4.2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrafficMatrix {
    n: usize,
    /// Row-major `n * n` token counts.
    data: Vec<u64>,
}

impl TrafficMatrix {
    /// All-zero matrix.
    pub fn zeros(n: usize) -> Self {
        Self {
            n,
            data: vec![0; n * n],
        }
    }

    /// Build from a row-major slice. Panics if `data.len() != n * n`.
    pub fn from_rows(n: usize, data: &[u64]) -> Self {
        assert_eq!(data.len(), n * n, "traffic matrix shape mismatch");
        Self {
            n,
            data: data.to_vec(),
        }
    }

    /// Build from a nested vec of rows.
    pub fn from_nested(rows: &[Vec<u64>]) -> Self {
        let n = rows.len();
        let mut data = Vec::with_capacity(n * n);
        for r in rows {
            assert_eq!(r.len(), n, "traffic matrix must be square");
            data.extend_from_slice(r);
        }
        Self { n, data }
    }

    /// Number of GPUs (matrix dimension).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Tokens sent from `i` to `j`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> u64 {
        self.data[i * self.n + j]
    }

    /// Set the `(i, j)` entry.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: u64) {
        self.data[i * self.n + j] = v;
    }

    /// Add `v` tokens to the `(i, j)` entry.
    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: u64) {
        self.data[i * self.n + j] += v;
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[u64] {
        &self.data
    }

    /// Sum of row `i` *excluding* the diagonal: total tokens GPU `i` puts on
    /// the wire.
    pub fn row_sum(&self, i: usize) -> u64 {
        (0..self.n)
            .filter(|&j| j != i)
            .map(|j| self.get(i, j))
            .sum()
    }

    /// Sum of column `j` *excluding* the diagonal: total tokens GPU `j`
    /// receives from the wire.
    pub fn col_sum(&self, j: usize) -> u64 {
        (0..self.n)
            .filter(|&i| i != j)
            .map(|i| self.get(i, j))
            .sum()
    }

    /// Total off-diagonal tokens.
    pub fn total(&self) -> u64 {
        (0..self.n).map(|i| self.row_sum(i)).sum()
    }

    /// `b_max` in tokens (bandwidth-free): the largest per-GPU send or receive
    /// volume, the lower bound of Theorem 4.2 (homogeneous, `B = 1`).
    pub fn b_max_tokens(&self) -> u64 {
        (0..self.n)
            .map(|i| self.row_sum(i).max(self.col_sum(i)))
            .max()
            .unwrap_or(0)
    }

    /// `b_max` in time units on a heterogeneous cluster (Theorem 5.2):
    /// `max_i max(Σ_j d_ij / B_i, Σ_j d_ji / B_i)` with `bandwidths[i]` in
    /// tokens per time unit.
    pub fn b_max_hetero(&self, bandwidths: &[f64]) -> f64 {
        assert_eq!(bandwidths.len(), self.n);
        (0..self.n)
            .map(|i| {
                let t = self.row_sum(i).max(self.col_sum(i)) as f64 / bandwidths[i];
                t
            })
            .fold(0.0, f64::max)
    }

    /// The reversed all-to-all matrix (`D_C = D_N^T`, §2.2): for every transfer
    /// `i → j` in the first collective there is an equal-size `j → i` transfer
    /// in the second.
    pub fn transpose(&self) -> Self {
        let mut t = Self::zeros(self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Element-wise sum (aggregated traffic of two colocated models whose
    /// experts already share GPU indices). Panics on shape mismatch.
    pub fn sum(&self, other: &Self) -> Self {
        assert_eq!(self.n, other.n);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Self { n: self.n, data }
    }

    /// Relabel GPUs: entry `(i, j)` of the result is `(perm[i], perm[j])` of
    /// `self`... more precisely, the result places the traffic of original
    /// index `i` at new index `perm[i]`: `out[perm[i]][perm[j]] = self[i][j]`.
    ///
    /// Used to express an expert colocation / GPU assignment as a relabeling
    /// of a model's traffic matrix.
    pub fn permute(&self, perm: &[usize]) -> Self {
        assert_eq!(perm.len(), self.n);
        let mut out = Self::zeros(self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                out.set(perm[i], perm[j], self.get(i, j));
            }
        }
        out
    }

    /// Per-GPU token load of the experts: column sums *including* the diagonal
    /// (every token routed to expert `j` is processed by GPU `j`, whether or
    /// not it crossed the network). Drives FFN compute times and Theorem 5.1.
    pub fn expert_loads(&self) -> Vec<u64> {
        (0..self.n)
            .map(|j| (0..self.n).map(|i| self.get(i, j)).sum())
            .collect()
    }

    /// All off-diagonal non-zero flows as `(src, dst, tokens)`.
    pub fn flows(&self) -> Vec<(usize, usize, u64)> {
        let mut out = Vec::new();
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j && self.get(i, j) > 0 {
                    out.push((i, j, self.get(i, j)));
                }
            }
        }
        out
    }

    /// Project an **expert-indexed** matrix onto **GPU indices** under an
    /// arbitrary placement: `owner[e]` is the GPU hosting expert `e`, and the
    /// result is `m × m` with `out[owner[i]][owner[j]] += self[i][j]`.
    ///
    /// Unlike [`TrafficMatrix::permute`] this does not require a bijection:
    /// several experts may share one GPU (their traffic aggregates, and
    /// traffic between co-hosted experts lands on the diagonal, i.e. becomes
    /// local), and the GPU count `m` may differ from the expert count. When
    /// `owner` *is* a permutation and `m == n`, the result is identical to
    /// `permute(owner)`.
    pub fn project(&self, owner: &[usize], m: usize) -> Self {
        assert_eq!(owner.len(), self.n, "one owner GPU per expert");
        assert!(
            owner.iter().all(|&g| g < m),
            "owner GPU out of range (m = {m})"
        );
        let mut out = Self::zeros(m);
        for i in 0..self.n {
            for j in 0..self.n {
                out.add(owner[i], owner[j], self.get(i, j));
            }
        }
        out
    }

    /// [`TrafficMatrix::project`] generalized to **replicated** destination
    /// experts: `owner[e]` is the GPU hosting expert `e`'s *primary* copy
    /// (the source of row `e`), while tokens routed *to* expert `j` split
    /// across `replicas[j]` (GPU ids) according to the fractional
    /// `weights[j]` (same length, summing to 1). Fractions are integerized
    /// per flow by largest-remainder rounding (deterministic: remainder
    /// tokens go to the replicas with the largest fractional parts, ties to
    /// the lower replica index), so token conservation is exact.
    ///
    /// When every replica set is a singleton `[owner[j]]` with weight
    /// `[1.0]`, the result is bit-for-bit identical to
    /// `project(owner, m)` — replication degrades to plain placement.
    pub fn project_split(
        &self,
        owner: &[usize],
        replicas: &[Vec<usize>],
        weights: &[Vec<f64>],
        m: usize,
    ) -> Self {
        assert_eq!(owner.len(), self.n, "one primary GPU per expert");
        assert_eq!(replicas.len(), self.n, "one replica set per expert");
        assert_eq!(weights.len(), self.n, "one weight vector per expert");
        assert!(
            owner.iter().all(|&g| g < m),
            "owner GPU out of range (m = {m})"
        );
        for (j, set) in replicas.iter().enumerate() {
            assert!(!set.is_empty(), "expert {j} has an empty replica set");
            assert_eq!(
                set.len(),
                weights[j].len(),
                "expert {j}: one weight per replica"
            );
            assert!(
                set.iter().all(|&g| g < m),
                "expert {j}: replica GPU out of range (m = {m})"
            );
        }
        let mut out = Self::zeros(m);
        for i in 0..self.n {
            let src = owner[i];
            for j in 0..self.n {
                let t = self.get(i, j);
                if t == 0 {
                    continue;
                }
                let set = &replicas[j];
                if set.len() == 1 {
                    out.add(src, set[0], t);
                    continue;
                }
                for (r, part) in split_tokens(t, &weights[j]).into_iter().enumerate() {
                    if part > 0 {
                        out.add(src, set[r], part);
                    }
                }
            }
        }
        out
    }

    /// Merge pairs of GPUs: `groups[g]` lists the original indices fused onto
    /// new GPU `g`. Traffic between members of the same group becomes local
    /// (kept on the diagonal so expert loads stay correct). Used by the Lina
    /// baseline, which packs two experts of the *same* model per GPU.
    pub fn merge_groups(&self, groups: &[Vec<usize>]) -> Self {
        let m = groups.len();
        let mut owner = vec![usize::MAX; self.n];
        for (g, members) in groups.iter().enumerate() {
            for &i in members {
                assert!(i < self.n && owner[i] == usize::MAX, "bad grouping");
                owner[i] = g;
            }
        }
        assert!(
            owner.iter().all(|&o| o != usize::MAX),
            "grouping must cover all GPUs"
        );
        let mut out = Self::zeros(m);
        for i in 0..self.n {
            for j in 0..self.n {
                out.add(owner[i], owner[j], self.get(i, j));
            }
        }
        out
    }
}

/// Apportion `tokens` across fractional `weights` (non-negative, summing to
/// roughly 1) with largest-remainder rounding: every share is floored, then
/// the leftover tokens go one-by-one to the entries with the largest
/// fractional parts (ties broken toward the lower index). The returned parts
/// always sum to exactly `tokens`, which is what keeps replica-split traffic
/// matrices conservation-exact. All-zero weights put everything on index 0.
pub fn split_tokens(tokens: u64, weights: &[f64]) -> Vec<u64> {
    assert!(!weights.is_empty(), "split needs at least one weight");
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        let mut parts = vec![0u64; weights.len()];
        parts[0] = tokens;
        return parts;
    }
    let mut parts = Vec::with_capacity(weights.len());
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(weights.len());
    let mut assigned = 0u64;
    for (r, &w) in weights.iter().enumerate() {
        let exact = tokens as f64 * (w / total);
        let floor = exact.floor() as u64;
        parts.push(floor);
        assigned += floor;
        fracs.push((r, exact - floor as f64));
    }
    // Largest fractional parts first; ties to the lower replica index.
    fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut rest = tokens - assigned;
    let mut k = 0;
    while rest > 0 {
        parts[fracs[k % fracs.len()].0] += 1;
        rest -= 1;
        k += 1;
    }
    parts
}

impl fmt::Display for TrafficMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in 0..self.n {
            for j in 0..self.n {
                write!(f, "{:>6}", self.get(i, j))?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrafficMatrix {
        TrafficMatrix::from_nested(&[vec![5, 2, 3], vec![4, 0, 1], vec![0, 6, 7]])
    }

    #[test]
    fn row_col_sums_exclude_diagonal() {
        let m = sample();
        assert_eq!(m.row_sum(0), 5); // 2 + 3
        assert_eq!(m.row_sum(1), 5); // 4 + 1
        assert_eq!(m.row_sum(2), 6); // 0 + 6
        assert_eq!(m.col_sum(0), 4);
        assert_eq!(m.col_sum(1), 8);
        assert_eq!(m.col_sum(2), 4);
        assert_eq!(m.total(), 16);
    }

    #[test]
    fn b_max_is_max_row_or_col() {
        let m = sample();
        assert_eq!(m.b_max_tokens(), 8); // col 1
    }

    #[test]
    fn transpose_reverses_flows() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.get(1, 0), m.get(0, 1));
        assert_eq!(t.b_max_tokens(), m.b_max_tokens());
    }

    #[test]
    fn expert_loads_include_diagonal() {
        let m = sample();
        assert_eq!(m.expert_loads(), vec![9, 8, 11]);
    }

    #[test]
    fn permute_relabels_consistently() {
        let m = sample();
        let p = m.permute(&[2, 0, 1]);
        // original (0,1)=2 should land at (2,0)
        assert_eq!(p.get(2, 0), 2);
        assert_eq!(p.total(), m.total());
        assert_eq!(p.b_max_tokens(), m.b_max_tokens());
    }

    #[test]
    fn sum_adds_elementwise() {
        let m = sample();
        let s = m.sum(&m);
        assert_eq!(s.get(2, 1), 12);
        assert_eq!(s.total(), 2 * m.total());
    }

    #[test]
    fn hetero_b_max_scales_by_bandwidth() {
        let m = sample();
        let b = m.b_max_hetero(&[1.0, 2.0, 1.0]);
        // GPU0: max(5,4)/1=5, GPU1: max(5,8)/2=4, GPU2: max(6,4)/1=6
        assert!((b - 6.0).abs() < 1e-12);
    }

    #[test]
    fn flows_skip_diagonal_and_zeros() {
        let m = sample();
        let fs = m.flows();
        assert_eq!(fs.len(), 5);
        assert!(fs.iter().all(|&(i, j, d)| i != j && d > 0));
    }

    #[test]
    fn project_matches_permute_for_bijections() {
        let m = sample();
        let p = vec![2usize, 0, 1];
        assert_eq!(m.project(&p, 3), m.permute(&p));
    }

    #[test]
    fn project_aggregates_and_localizes() {
        let m = TrafficMatrix::from_nested(&[
            vec![0, 1, 2, 3],
            vec![4, 0, 5, 6],
            vec![7, 8, 0, 9],
            vec![1, 1, 1, 0],
        ]);
        // experts 0 and 1 share GPU 0; experts 2 and 3 share GPU 1
        let g = m.project(&[0, 0, 1, 1], 2);
        assert_eq!(g.n(), 2);
        assert_eq!(g.get(0, 1), 2 + 3 + 5 + 6);
        // intra-GPU traffic became local (diagonal)
        assert_eq!(g.get(0, 0), 1 + 4);
        // total token load is conserved
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
        // network volume can only shrink (localization)
        assert!(g.total() <= m.total());
    }

    #[test]
    #[should_panic]
    fn project_rejects_out_of_range_owner() {
        sample().project(&[0, 1, 3], 3);
    }

    #[test]
    fn split_tokens_conserves_and_follows_weights() {
        assert_eq!(split_tokens(10, &[1.0]), vec![10]);
        assert_eq!(split_tokens(10, &[0.5, 0.5]), vec![5, 5]);
        assert_eq!(split_tokens(9, &[0.5, 0.5]), vec![5, 4]); // tie -> lower index
        // exact shares 7.5/2.5 floor to 7+2; the leftover token goes to the
        // lower index on the fractional tie
        assert_eq!(split_tokens(10, &[0.75, 0.25]), vec![8, 2]);
        assert_eq!(split_tokens(0, &[0.3, 0.7]), vec![0, 0]);
        // all-zero weights collapse onto the first entry
        assert_eq!(split_tokens(7, &[0.0, 0.0, 0.0]), vec![7, 0, 0]);
        // unnormalized weights are fine
        let parts = split_tokens(100, &[3.0, 1.0]);
        assert_eq!(parts, vec![75, 25]);
        for t in [1u64, 13, 97, 1000] {
            let parts = split_tokens(t, &[0.41, 0.13, 0.46]);
            assert_eq!(parts.iter().sum::<u64>(), t);
        }
    }

    #[test]
    fn split_tokens_single_replica_is_identity() {
        for t in [0u64, 1, 7, 1_000_000] {
            assert_eq!(split_tokens(t, &[0.37]), vec![t]);
            // weight magnitude is irrelevant for a single replica
            assert_eq!(split_tokens(t, &[1e-12]), vec![t]);
        }
    }

    #[test]
    fn split_tokens_zero_tokens_yield_all_zero_parts() {
        for w in [
            vec![1.0],
            vec![0.5, 0.5],
            vec![0.0, 0.0, 0.0],
            vec![1e-9, 1e9],
        ] {
            let parts = split_tokens(0, &w);
            assert_eq!(parts.len(), w.len());
            assert!(parts.iter().all(|&p| p == 0), "{w:?} -> {parts:?}");
        }
    }

    #[test]
    fn split_tokens_all_equal_remainders_break_toward_lower_indices() {
        // 10 tokens over 4 equal weights: every exact share is 2.5, so the
        // two leftover tokens must go to replicas 0 and 1, in order.
        assert_eq!(split_tokens(10, &[0.25; 4]), vec![3, 3, 2, 2]);
        // 3 over 4 equal weights: fractional parts all tie at 0.75
        assert_eq!(split_tokens(3, &[1.0; 4]), vec![1, 1, 1, 0]);
        // ties are by fractional part, not weight scale
        assert_eq!(split_tokens(10, &[2.5; 4]), vec![3, 3, 2, 2]);
    }

    #[test]
    fn split_tokens_conserves_under_adversarial_weights() {
        use crate::util::Rng;
        let adversarial: Vec<Vec<f64>> = vec![
            vec![1e-300, 1.0],            // denormal-scale weight
            vec![1e300, 1.0],             // huge imbalance
            vec![0.0, 1.0, 0.0],          // zeros inside
            vec![f64::MIN_POSITIVE; 5],   // all tiny
            vec![0.1; 10],                // many equal
            vec![0.9999999, 0.0000001],   // near-degenerate
        ];
        for w in &adversarial {
            for t in [0u64, 1, 2, 999, 12_345] {
                let parts = split_tokens(t, w);
                assert_eq!(parts.len(), w.len());
                assert_eq!(parts.iter().sum::<u64>(), t, "weights {w:?} tokens {t}");
            }
        }
        // seeded random weight vectors: conservation and floor/ceil bounds
        let mut rng = Rng::new(0x5EED5);
        for _ in 0..200 {
            let k = rng.gen_range(6) as usize + 1;
            let w: Vec<f64> = (0..k).map(|_| rng.gen_f64()).collect();
            let t = rng.gen_range(10_000);
            let parts = split_tokens(t, &w);
            assert_eq!(parts.iter().sum::<u64>(), t);
            let total: f64 = w.iter().sum();
            if total > 0.0 {
                for (r, &p) in parts.iter().enumerate() {
                    let exact = t as f64 * (w[r] / total);
                    // largest-remainder: every part is its floor or ceiling
                    assert!(
                        (p as f64) >= exact.floor() - 1e-9 && (p as f64) <= exact.ceil() + 1e-9,
                        "part {r}={p} vs exact {exact} (weights {w:?}, tokens {t})"
                    );
                }
            }
        }
    }

    #[test]
    fn project_split_zero_rows_conserve() {
        // senders 1 and 2 originate nothing: splitting must not invent tokens
        let m = TrafficMatrix::from_nested(&[
            vec![0, 30, 0],
            vec![0, 0, 0],
            vec![0, 0, 0],
        ]);
        let owner = vec![0usize, 1, 2];
        let replicas = vec![vec![0], vec![1, 2], vec![2]];
        let weights = vec![vec![1.0], vec![0.5, 0.5], vec![1.0]];
        let g = m.project_split(&owner, &replicas, &weights, 3);
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
        assert_eq!(g.row_sum(1), 0);
        assert_eq!(g.row_sum(2), 0);
        assert_eq!(g.get(0, 1) + g.get(0, 2), 30);
    }

    #[test]
    fn project_split_singletons_match_project_bitwise() {
        let m = sample();
        let owner = vec![2usize, 0, 1];
        let replicas: Vec<Vec<usize>> = owner.iter().map(|&g| vec![g]).collect();
        let weights: Vec<Vec<f64>> = owner.iter().map(|_| vec![1.0]).collect();
        assert_eq!(
            m.project_split(&owner, &replicas, &weights, 3),
            m.project(&owner, 3)
        );
    }

    #[test]
    fn project_split_spreads_hot_column_and_conserves() {
        // 4 experts on 2 GPUs; expert 0 (on GPU 0) is replicated onto GPU 1
        // with a 50/50 split.
        let m = TrafficMatrix::from_nested(&[
            vec![0, 2, 2, 2],
            vec![40, 0, 1, 1],
            vec![40, 1, 0, 1],
            vec![40, 1, 1, 0],
        ]);
        let owner = vec![0usize, 0, 1, 1];
        let replicas = vec![vec![0usize, 1], vec![0], vec![1], vec![1]];
        let weights = vec![vec![0.5, 0.5], vec![1.0], vec![1.0], vec![1.0]];
        let g = m.project_split(&owner, &replicas, &weights, 2);
        // token load is conserved
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
        // expert 0's 120 inbound tokens split between the two GPUs, so GPU
        // 0's receive column shrinks vs the unsplit projection
        let unsplit = m.project(&owner, 2);
        assert!(g.col_sum(0) < unsplit.col_sum(0));
        assert!(g.b_max_tokens() < unsplit.b_max_tokens());
    }

    #[test]
    #[should_panic]
    fn project_split_rejects_mismatched_weights() {
        let m = sample();
        m.project_split(
            &[0, 1, 2],
            &[vec![0, 1], vec![1], vec![2]],
            &[vec![1.0], vec![1.0], vec![1.0]],
            3,
        );
    }

    #[test]
    fn merge_groups_localizes_intra_group_traffic() {
        let m = TrafficMatrix::from_nested(&[
            vec![0, 1, 2, 3],
            vec![4, 0, 5, 6],
            vec![7, 8, 0, 9],
            vec![1, 1, 1, 0],
        ]);
        let g = m.merge_groups(&[vec![0, 1], vec![2, 3]]);
        assert_eq!(g.n(), 2);
        // inter-group 0->1: (0,2)+(0,3)+(1,2)+(1,3) = 2+3+5+6 = 16
        assert_eq!(g.get(0, 1), 16);
        // intra-group traffic moved onto the diagonal: (0,1)+(1,0) = 5
        assert_eq!(g.get(0, 0), 5);
        // expert load is conserved in total
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
    }
}
