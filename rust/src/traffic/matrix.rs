//! The [`TrafficMatrix`] type and its row/column/bound arithmetic.

use std::fmt;

/// Matrices at least this large are eligible for the sparse representation;
/// below it the dense row-major buffer is always faster.
const SPARSE_MIN_N: usize = 64;

/// Density cut-off: a constructor picks the sparse representation when fewer
/// than one cell in `SPARSE_DENSITY_DIV` is nonzero.
const SPARSE_DENSITY_DIV: usize = 4;

/// Why a traffic matrix could not be constructed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrafficError {
    /// [`TrafficMatrix::from_rows`] got a buffer whose length is not `n * n`.
    ShapeMismatch {
        /// Requested dimension.
        n: usize,
        /// Actual buffer length.
        len: usize,
    },
    /// [`TrafficMatrix::from_nested`] got a row whose length differs from the
    /// row count (the matrix must be square).
    RowLengthMismatch {
        /// Offending row index.
        row: usize,
        /// That row's length.
        len: usize,
        /// Expected length (the number of rows).
        n: usize,
    },
}

impl fmt::Display for TrafficError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrafficError::ShapeMismatch { n, len } => {
                write!(f, "traffic matrix shape mismatch: {n}x{n} needs {} cells, got {len}", n * n)
            }
            TrafficError::RowLengthMismatch { row, len, n } => {
                write!(f, "traffic matrix must be square: row {row} has {len} cells, expected {n}")
            }
        }
    }
}

impl std::error::Error for TrafficError {}

/// Internal storage of a [`TrafficMatrix`].
///
/// `Dense` is the historical row-major buffer. `Sparse` keeps the nonzero
/// cells twice — CSR-style by row and CSC-style by column, each list sorted
/// by index — so row scans, column scans, and transposes are all
/// O(nonzeros). Every operation produces identical *values* on either
/// representation (all token arithmetic is exact integer arithmetic), which
/// is the bit-for-bit contract the property tests pin.
#[derive(Debug, Clone)]
enum Repr {
    /// Row-major `n * n` token counts.
    Dense(Vec<u64>),
    /// Nonzero cells only, sorted by the inner index.
    Sparse {
        /// `rows[i]` = ascending `(col, tokens)` with `tokens > 0`.
        rows: Vec<Vec<(usize, u64)>>,
        /// `cols[j]` = ascending `(row, tokens)` with `tokens > 0`.
        cols: Vec<Vec<(usize, u64)>>,
    },
}

/// An `n × n` all-to-all traffic matrix in integer tokens.
///
/// Entry `(i, j)` is the number of tokens GPU `i` sends to GPU `j`.
/// Diagonal entries represent tokens whose source and destination expert live
/// on the same GPU; they never touch the network and are ignored by every
/// communication-time computation (paper footnote 1, §4.2).
///
/// Storage is dense row-major or CSR/CSC sparse; constructors pick by
/// density ([`TrafficMatrix::from_rows`], [`TrafficMatrix::from_nested`],
/// and the projection/aggregation operators) while [`TrafficMatrix::zeros`]
/// plus `set`/`add` always stays dense. [`TrafficMatrix::to_sparse`] /
/// [`TrafficMatrix::to_dense`] force a representation; equality is
/// *semantic* (same dimension, same cells), never representational.
#[derive(Debug, Clone)]
pub struct TrafficMatrix {
    n: usize,
    repr: Repr,
}

impl PartialEq for TrafficMatrix {
    fn eq(&self, other: &Self) -> bool {
        if self.n != other.n {
            return false;
        }
        match (&self.repr, &other.repr) {
            (Repr::Dense(a), Repr::Dense(b)) => a == b,
            (Repr::Sparse { rows: a, .. }, Repr::Sparse { rows: b, .. }) => a == b,
            _ => (0..self.n).all(|i| {
                let a: Vec<(usize, u64)> = self.row_iter(i).collect();
                let b: Vec<(usize, u64)> = other.row_iter(i).collect();
                a == b
            }),
        }
    }
}

impl Eq for TrafficMatrix {}

/// Set `list[key] = v` in a sorted sparse list (removing the entry when
/// `v == 0`).
fn sparse_set(list: &mut Vec<(usize, u64)>, key: usize, v: u64) {
    match list.binary_search_by_key(&key, |&(k, _)| k) {
        Ok(p) => {
            if v == 0 {
                list.remove(p);
            } else {
                list[p].1 = v;
            }
        }
        Err(p) => {
            if v > 0 {
                list.insert(p, (key, v));
            }
        }
    }
}

/// Add `v > 0` to `list[key]` in a sorted sparse list.
fn sparse_add(list: &mut Vec<(usize, u64)>, key: usize, v: u64) {
    match list.binary_search_by_key(&key, |&(k, _)| k) {
        Ok(p) => list[p].1 += v,
        Err(p) => list.insert(p, (key, v)),
    }
}

/// Iterator over the nonzero cells of one row or column, ascending by index.
pub struct NonzeroIter<'a> {
    inner: NonzeroInner<'a>,
}

enum NonzeroInner<'a> {
    /// Strided dense walk: element `k` lives at `cells[k * step]`.
    Dense {
        cells: &'a [u64],
        step: usize,
        k: usize,
        count: usize,
    },
    Sparse(std::slice::Iter<'a, (usize, u64)>),
}

impl Iterator for NonzeroIter<'_> {
    type Item = (usize, u64);

    fn next(&mut self) -> Option<(usize, u64)> {
        match &mut self.inner {
            NonzeroInner::Dense {
                cells,
                step,
                k,
                count,
            } => {
                while *k < *count {
                    let key = *k;
                    let v = cells[key * *step];
                    *k += 1;
                    if v > 0 {
                        return Some((key, v));
                    }
                }
                None
            }
            NonzeroInner::Sparse(it) => it.next().copied(),
        }
    }
}

impl TrafficMatrix {
    /// All-zero matrix (always dense, so `set`/`add` loops stay O(1) per
    /// cell).
    pub fn zeros(n: usize) -> Self {
        Self {
            n,
            repr: Repr::Dense(vec![0; n * n]),
        }
    }

    /// Pick the representation for a finished dense buffer by density.
    fn from_dense_auto(n: usize, data: Vec<u64>) -> Self {
        if n >= SPARSE_MIN_N {
            let nnz = data.iter().filter(|&&v| v > 0).count();
            if nnz * SPARSE_DENSITY_DIV < n * n {
                return Self::sparse_from_slice(n, &data);
            }
        }
        Self {
            n,
            repr: Repr::Dense(data),
        }
    }

    /// Build the sparse representation from a dense row-major slice.
    fn sparse_from_slice(n: usize, data: &[u64]) -> Self {
        let mut rows: Vec<Vec<(usize, u64)>> = vec![Vec::new(); n];
        let mut cols: Vec<Vec<(usize, u64)>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                let v = data[i * n + j];
                if v > 0 {
                    rows[i].push((j, v));
                    cols[j].push((i, v));
                }
            }
        }
        Self {
            n,
            repr: Repr::Sparse { rows, cols },
        }
    }

    /// Build from a row-major slice, choosing the representation by density.
    /// Errors when `data.len() != n * n`.
    pub fn from_rows(n: usize, data: &[u64]) -> Result<Self, TrafficError> {
        if data.len() != n * n {
            return Err(TrafficError::ShapeMismatch { n, len: data.len() });
        }
        Ok(Self::from_dense_auto(n, data.to_vec()))
    }

    /// Build from a nested vec of rows, choosing the representation by
    /// density. Errors when any row's length differs from the row count.
    pub fn from_nested(rows: &[Vec<u64>]) -> Result<Self, TrafficError> {
        let n = rows.len();
        let mut data = Vec::with_capacity(n * n);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != n {
                return Err(TrafficError::RowLengthMismatch {
                    row: i,
                    len: r.len(),
                    n,
                });
            }
            data.extend_from_slice(r);
        }
        Ok(Self::from_dense_auto(n, data))
    }

    /// Number of GPUs (matrix dimension).
    pub fn n(&self) -> usize {
        self.n
    }

    /// True when the matrix is stored sparsely.
    pub fn is_sparse(&self) -> bool {
        matches!(self.repr, Repr::Sparse { .. })
    }

    /// Number of nonzero cells (diagonal included).
    pub fn nnz(&self) -> usize {
        match &self.repr {
            Repr::Dense(d) => d.iter().filter(|&&v| v > 0).count(),
            Repr::Sparse { rows, .. } => rows.iter().map(|r| r.len()).sum(),
        }
    }

    /// The same matrix in the sparse representation (regardless of density).
    pub fn to_sparse(&self) -> Self {
        match &self.repr {
            Repr::Dense(d) => Self::sparse_from_slice(self.n, d),
            Repr::Sparse { .. } => self.clone(),
        }
    }

    /// The same matrix in the dense representation.
    pub fn to_dense(&self) -> Self {
        Self {
            n: self.n,
            repr: Repr::Dense(self.dense_vec()),
        }
    }

    /// Re-pick the representation by density — use after building a large
    /// matrix cell-by-cell on top of [`TrafficMatrix::zeros`].
    pub fn compact(self) -> Self {
        match self.repr {
            Repr::Dense(d) => Self::from_dense_auto(self.n, d),
            Repr::Sparse { .. } => self,
        }
    }

    /// Row-major copy of all `n * n` cells.
    pub fn dense_vec(&self) -> Vec<u64> {
        match &self.repr {
            Repr::Dense(d) => d.clone(),
            Repr::Sparse { rows, .. } => {
                let mut out = vec![0u64; self.n * self.n];
                for (i, row) in rows.iter().enumerate() {
                    for &(j, v) in row {
                        out[i * self.n + j] = v;
                    }
                }
                out
            }
        }
    }

    /// Tokens sent from `i` to `j`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> u64 {
        assert!(i < self.n && j < self.n, "traffic index out of range");
        match &self.repr {
            Repr::Dense(d) => d[i * self.n + j],
            Repr::Sparse { rows, .. } => match rows[i].binary_search_by_key(&j, |&(c, _)| c) {
                Ok(p) => rows[i][p].1,
                Err(_) => 0,
            },
        }
    }

    /// Set the `(i, j)` entry.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: u64) {
        assert!(i < self.n && j < self.n, "traffic index out of range");
        match &mut self.repr {
            Repr::Dense(d) => d[i * self.n + j] = v,
            Repr::Sparse { rows, cols } => {
                sparse_set(&mut rows[i], j, v);
                sparse_set(&mut cols[j], i, v);
            }
        }
    }

    /// Add `v` tokens to the `(i, j)` entry.
    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: u64) {
        assert!(i < self.n && j < self.n, "traffic index out of range");
        if v == 0 {
            return;
        }
        match &mut self.repr {
            Repr::Dense(d) => d[i * self.n + j] += v,
            Repr::Sparse { rows, cols } => {
                sparse_add(&mut rows[i], j, v);
                sparse_add(&mut cols[j], i, v);
            }
        }
    }

    /// Nonzero cells of row `i` as ascending `(col, tokens)` — O(row
    /// nonzeros) on the sparse representation.
    pub fn row_iter(&self, i: usize) -> NonzeroIter<'_> {
        assert!(i < self.n, "traffic index out of range");
        NonzeroIter {
            inner: match &self.repr {
                Repr::Dense(d) => NonzeroInner::Dense {
                    cells: &d[i * self.n..(i + 1) * self.n],
                    step: 1,
                    k: 0,
                    count: self.n,
                },
                Repr::Sparse { rows, .. } => NonzeroInner::Sparse(rows[i].iter()),
            },
        }
    }

    /// Nonzero cells of column `j` as ascending `(row, tokens)` — O(column
    /// nonzeros) on the sparse representation.
    pub fn col_iter(&self, j: usize) -> NonzeroIter<'_> {
        assert!(j < self.n, "traffic index out of range");
        NonzeroIter {
            inner: match &self.repr {
                Repr::Dense(d) => NonzeroInner::Dense {
                    cells: &d[j..],
                    step: self.n,
                    k: 0,
                    count: self.n,
                },
                Repr::Sparse { cols, .. } => NonzeroInner::Sparse(cols[j].iter()),
            },
        }
    }

    /// Sum of row `i` *excluding* the diagonal: total tokens GPU `i` puts on
    /// the wire.
    pub fn row_sum(&self, i: usize) -> u64 {
        self.row_iter(i)
            .filter(|&(j, _)| j != i)
            .map(|(_, v)| v)
            .sum()
    }

    /// Sum of column `j` *excluding* the diagonal: total tokens GPU `j`
    /// receives from the wire.
    pub fn col_sum(&self, j: usize) -> u64 {
        self.col_iter(j)
            .filter(|&(i, _)| i != j)
            .map(|(_, v)| v)
            .sum()
    }

    /// Total off-diagonal tokens.
    pub fn total(&self) -> u64 {
        (0..self.n).map(|i| self.row_sum(i)).sum()
    }

    /// `b_max` in tokens (bandwidth-free): the largest per-GPU send or receive
    /// volume, the lower bound of Theorem 4.2 (homogeneous, `B = 1`).
    pub fn b_max_tokens(&self) -> u64 {
        (0..self.n)
            .map(|i| self.row_sum(i).max(self.col_sum(i)))
            .max()
            .unwrap_or(0)
    }

    /// `b_max` in time units on a heterogeneous cluster (Theorem 5.2):
    /// `max_i max(Σ_j d_ij / B_i, Σ_j d_ji / B_i)` with `bandwidths[i]` in
    /// tokens per time unit.
    pub fn b_max_hetero(&self, bandwidths: &[f64]) -> f64 {
        assert_eq!(bandwidths.len(), self.n);
        (0..self.n)
            .map(|i| {
                let t = self.row_sum(i).max(self.col_sum(i)) as f64 / bandwidths[i];
                t
            })
            .fold(0.0, f64::max)
    }

    /// The reversed all-to-all matrix (`D_C = D_N^T`, §2.2): for every transfer
    /// `i → j` in the first collective there is an equal-size `j → i` transfer
    /// in the second.
    pub fn transpose(&self) -> Self {
        match &self.repr {
            Repr::Dense(_) => {
                let mut t = Self::zeros(self.n);
                for i in 0..self.n {
                    for (j, v) in self.row_iter(i) {
                        t.set(j, i, v);
                    }
                }
                t
            }
            // The CSR/CSC pair is its own transpose with the roles swapped.
            Repr::Sparse { rows, cols } => Self {
                n: self.n,
                repr: Repr::Sparse {
                    rows: cols.clone(),
                    cols: rows.clone(),
                },
            },
        }
    }

    /// Element-wise sum (aggregated traffic of two colocated models whose
    /// experts already share GPU indices). Panics on shape mismatch.
    pub fn sum(&self, other: &Self) -> Self {
        assert_eq!(self.n, other.n);
        if let (Repr::Dense(a), Repr::Dense(b)) = (&self.repr, &other.repr) {
            let data = a.iter().zip(b).map(|(x, y)| x + y).collect();
            return Self {
                n: self.n,
                repr: Repr::Dense(data),
            };
        }
        let mut data = self.dense_vec();
        for i in 0..self.n {
            for (j, v) in other.row_iter(i) {
                data[i * self.n + j] += v;
            }
        }
        Self::from_dense_auto(self.n, data)
    }

    /// Relabel GPUs: entry `(i, j)` of the result is `(perm[i], perm[j])` of
    /// `self`... more precisely, the result places the traffic of original
    /// index `i` at new index `perm[i]`: `out[perm[i]][perm[j]] = self[i][j]`.
    ///
    /// Used to express an expert colocation / GPU assignment as a relabeling
    /// of a model's traffic matrix.
    pub fn permute(&self, perm: &[usize]) -> Self {
        assert_eq!(perm.len(), self.n);
        let mut out = vec![0u64; self.n * self.n];
        for i in 0..self.n {
            for (j, v) in self.row_iter(i) {
                out[perm[i] * self.n + perm[j]] = v;
            }
        }
        if self.is_sparse() {
            Self::from_dense_auto(self.n, out)
        } else {
            Self {
                n: self.n,
                repr: Repr::Dense(out),
            }
        }
    }

    /// Per-GPU token load of the experts: column sums *including* the diagonal
    /// (every token routed to expert `j` is processed by GPU `j`, whether or
    /// not it crossed the network). Drives FFN compute times and Theorem 5.1.
    pub fn expert_loads(&self) -> Vec<u64> {
        (0..self.n)
            .map(|j| self.col_iter(j).map(|(_, v)| v).sum())
            .collect()
    }

    /// All off-diagonal non-zero flows as `(src, dst, tokens)`.
    pub fn flows(&self) -> Vec<(usize, usize, u64)> {
        let mut out = Vec::new();
        for i in 0..self.n {
            for (j, v) in self.row_iter(i) {
                if i != j {
                    out.push((i, j, v));
                }
            }
        }
        out
    }

    /// Project an **expert-indexed** matrix onto **GPU indices** under an
    /// arbitrary placement: `owner[e]` is the GPU hosting expert `e`, and the
    /// result is `m × m` with `out[owner[i]][owner[j]] += self[i][j]`.
    ///
    /// Unlike [`TrafficMatrix::permute`] this does not require a bijection:
    /// several experts may share one GPU (their traffic aggregates, and
    /// traffic between co-hosted experts lands on the diagonal, i.e. becomes
    /// local), and the GPU count `m` may differ from the expert count. When
    /// `owner` *is* a permutation and `m == n`, the result is identical to
    /// `permute(owner)`.
    pub fn project(&self, owner: &[usize], m: usize) -> Self {
        assert_eq!(owner.len(), self.n, "one owner GPU per expert");
        assert!(
            owner.iter().all(|&g| g < m),
            "owner GPU out of range (m = {m})"
        );
        let mut out = vec![0u64; m * m];
        for i in 0..self.n {
            let src = owner[i] * m;
            for (j, v) in self.row_iter(i) {
                out[src + owner[j]] += v;
            }
        }
        Self::from_dense_auto(m, out)
    }

    /// [`TrafficMatrix::project`] generalized to **replicated** destination
    /// experts: `owner[e]` is the GPU hosting expert `e`'s *primary* copy
    /// (the source of row `e`), while tokens routed *to* expert `j` split
    /// across `replicas[j]` (GPU ids) according to the fractional
    /// `weights[j]` (same length, summing to 1). Fractions are integerized
    /// per flow by largest-remainder rounding (deterministic: remainder
    /// tokens go to the replicas with the largest fractional parts, ties to
    /// the lower replica index), so token conservation is exact.
    ///
    /// When every replica set is a singleton `[owner[j]]` with weight
    /// `[1.0]`, the result is bit-for-bit identical to
    /// `project(owner, m)` — replication degrades to plain placement.
    pub fn project_split(
        &self,
        owner: &[usize],
        replicas: &[Vec<usize>],
        weights: &[Vec<f64>],
        m: usize,
    ) -> Self {
        assert_eq!(owner.len(), self.n, "one primary GPU per expert");
        assert_eq!(replicas.len(), self.n, "one replica set per expert");
        assert_eq!(weights.len(), self.n, "one weight vector per expert");
        assert!(
            owner.iter().all(|&g| g < m),
            "owner GPU out of range (m = {m})"
        );
        for (j, set) in replicas.iter().enumerate() {
            assert!(!set.is_empty(), "expert {j} has an empty replica set");
            assert_eq!(
                set.len(),
                weights[j].len(),
                "expert {j}: one weight per replica"
            );
            assert!(
                set.iter().all(|&g| g < m),
                "expert {j}: replica GPU out of range (m = {m})"
            );
        }
        let mut out = vec![0u64; m * m];
        for i in 0..self.n {
            let src = owner[i] * m;
            for (j, t) in self.row_iter(i) {
                let set = &replicas[j];
                if set.len() == 1 {
                    out[src + set[0]] += t;
                    continue;
                }
                for (r, part) in split_tokens(t, &weights[j]).into_iter().enumerate() {
                    if part > 0 {
                        out[src + set[r]] += part;
                    }
                }
            }
        }
        Self::from_dense_auto(m, out)
    }

    /// Merge pairs of GPUs: `groups[g]` lists the original indices fused onto
    /// new GPU `g`. Traffic between members of the same group becomes local
    /// (kept on the diagonal so expert loads stay correct). Used by the Lina
    /// baseline, which packs two experts of the *same* model per GPU.
    pub fn merge_groups(&self, groups: &[Vec<usize>]) -> Self {
        let m = groups.len();
        let mut owner = vec![usize::MAX; self.n];
        for (g, members) in groups.iter().enumerate() {
            for &i in members {
                assert!(i < self.n && owner[i] == usize::MAX, "bad grouping");
                owner[i] = g;
            }
        }
        assert!(
            owner.iter().all(|&o| o != usize::MAX),
            "grouping must cover all GPUs"
        );
        let mut out = vec![0u64; m * m];
        for i in 0..self.n {
            let src = owner[i] * m;
            for (j, v) in self.row_iter(i) {
                out[src + owner[j]] += v;
            }
        }
        Self::from_dense_auto(m, out)
    }
}

/// Apportion `tokens` across fractional `weights` (non-negative, summing to
/// roughly 1) with largest-remainder rounding: every share is floored, then
/// the leftover tokens go one-by-one to the entries with the largest
/// fractional parts (ties broken toward the lower index). The returned parts
/// always sum to exactly `tokens`, which is what keeps replica-split traffic
/// matrices conservation-exact. All-zero weights put everything on index 0.
pub fn split_tokens(tokens: u64, weights: &[f64]) -> Vec<u64> {
    assert!(!weights.is_empty(), "split needs at least one weight");
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        let mut parts = vec![0u64; weights.len()];
        parts[0] = tokens;
        return parts;
    }
    let mut parts = Vec::with_capacity(weights.len());
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(weights.len());
    let mut assigned = 0u64;
    for (r, &w) in weights.iter().enumerate() {
        let exact = tokens as f64 * (w / total);
        let floor = exact.floor() as u64;
        parts.push(floor);
        assigned += floor;
        fracs.push((r, exact - floor as f64));
    }
    // Largest fractional parts first; ties to the lower replica index.
    fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut rest = tokens - assigned;
    let mut k = 0;
    while rest > 0 {
        parts[fracs[k % fracs.len()].0] += 1;
        rest -= 1;
        k += 1;
    }
    parts
}

impl fmt::Display for TrafficMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in 0..self.n {
            for j in 0..self.n {
                write!(f, "{:>6}", self.get(i, j))?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrafficMatrix {
        TrafficMatrix::from_nested(&[vec![5, 2, 3], vec![4, 0, 1], vec![0, 6, 7]]).unwrap()
    }

    #[test]
    fn row_col_sums_exclude_diagonal() {
        let m = sample();
        assert_eq!(m.row_sum(0), 5); // 2 + 3
        assert_eq!(m.row_sum(1), 5); // 4 + 1
        assert_eq!(m.row_sum(2), 6); // 0 + 6
        assert_eq!(m.col_sum(0), 4);
        assert_eq!(m.col_sum(1), 8);
        assert_eq!(m.col_sum(2), 4);
        assert_eq!(m.total(), 16);
    }

    #[test]
    fn b_max_is_max_row_or_col() {
        let m = sample();
        assert_eq!(m.b_max_tokens(), 8); // col 1
    }

    #[test]
    fn transpose_reverses_flows() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.get(1, 0), m.get(0, 1));
        assert_eq!(t.b_max_tokens(), m.b_max_tokens());
    }

    #[test]
    fn expert_loads_include_diagonal() {
        let m = sample();
        assert_eq!(m.expert_loads(), vec![9, 8, 11]);
    }

    #[test]
    fn permute_relabels_consistently() {
        let m = sample();
        let p = m.permute(&[2, 0, 1]);
        // original (0,1)=2 should land at (2,0)
        assert_eq!(p.get(2, 0), 2);
        assert_eq!(p.total(), m.total());
        assert_eq!(p.b_max_tokens(), m.b_max_tokens());
    }

    #[test]
    fn sum_adds_elementwise() {
        let m = sample();
        let s = m.sum(&m);
        assert_eq!(s.get(2, 1), 12);
        assert_eq!(s.total(), 2 * m.total());
    }

    #[test]
    fn hetero_b_max_scales_by_bandwidth() {
        let m = sample();
        let b = m.b_max_hetero(&[1.0, 2.0, 1.0]);
        // GPU0: max(5,4)/1=5, GPU1: max(5,8)/2=4, GPU2: max(6,4)/1=6
        assert!((b - 6.0).abs() < 1e-12);
    }

    #[test]
    fn flows_skip_diagonal_and_zeros() {
        let m = sample();
        let fs = m.flows();
        assert_eq!(fs.len(), 5);
        assert!(fs.iter().all(|&(i, j, d)| i != j && d > 0));
    }

    #[test]
    fn project_matches_permute_for_bijections() {
        let m = sample();
        let p = vec![2usize, 0, 1];
        assert_eq!(m.project(&p, 3), m.permute(&p));
    }

    #[test]
    fn project_aggregates_and_localizes() {
        let m = TrafficMatrix::from_nested(&[
            vec![0, 1, 2, 3],
            vec![4, 0, 5, 6],
            vec![7, 8, 0, 9],
            vec![1, 1, 1, 0],
        ])
        .unwrap();
        // experts 0 and 1 share GPU 0; experts 2 and 3 share GPU 1
        let g = m.project(&[0, 0, 1, 1], 2);
        assert_eq!(g.n(), 2);
        assert_eq!(g.get(0, 1), 2 + 3 + 5 + 6);
        // intra-GPU traffic became local (diagonal)
        assert_eq!(g.get(0, 0), 1 + 4);
        // total token load is conserved
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
        // network volume can only shrink (localization)
        assert!(g.total() <= m.total());
    }

    #[test]
    #[should_panic]
    fn project_rejects_out_of_range_owner() {
        sample().project(&[0, 1, 3], 3);
    }

    #[test]
    fn split_tokens_conserves_and_follows_weights() {
        assert_eq!(split_tokens(10, &[1.0]), vec![10]);
        assert_eq!(split_tokens(10, &[0.5, 0.5]), vec![5, 5]);
        assert_eq!(split_tokens(9, &[0.5, 0.5]), vec![5, 4]); // tie -> lower index
        // exact shares 7.5/2.5 floor to 7+2; the leftover token goes to the
        // lower index on the fractional tie
        assert_eq!(split_tokens(10, &[0.75, 0.25]), vec![8, 2]);
        assert_eq!(split_tokens(0, &[0.3, 0.7]), vec![0, 0]);
        // all-zero weights collapse onto the first entry
        assert_eq!(split_tokens(7, &[0.0, 0.0, 0.0]), vec![7, 0, 0]);
        // unnormalized weights are fine
        let parts = split_tokens(100, &[3.0, 1.0]);
        assert_eq!(parts, vec![75, 25]);
        for t in [1u64, 13, 97, 1000] {
            let parts = split_tokens(t, &[0.41, 0.13, 0.46]);
            assert_eq!(parts.iter().sum::<u64>(), t);
        }
    }

    #[test]
    fn split_tokens_single_replica_is_identity() {
        for t in [0u64, 1, 7, 1_000_000] {
            assert_eq!(split_tokens(t, &[0.37]), vec![t]);
            // weight magnitude is irrelevant for a single replica
            assert_eq!(split_tokens(t, &[1e-12]), vec![t]);
        }
    }

    #[test]
    fn split_tokens_zero_tokens_yield_all_zero_parts() {
        for w in [
            vec![1.0],
            vec![0.5, 0.5],
            vec![0.0, 0.0, 0.0],
            vec![1e-9, 1e9],
        ] {
            let parts = split_tokens(0, &w);
            assert_eq!(parts.len(), w.len());
            assert!(parts.iter().all(|&p| p == 0), "{w:?} -> {parts:?}");
        }
    }

    #[test]
    fn split_tokens_all_equal_remainders_break_toward_lower_indices() {
        // 10 tokens over 4 equal weights: every exact share is 2.5, so the
        // two leftover tokens must go to replicas 0 and 1, in order.
        assert_eq!(split_tokens(10, &[0.25; 4]), vec![3, 3, 2, 2]);
        // 3 over 4 equal weights: fractional parts all tie at 0.75
        assert_eq!(split_tokens(3, &[1.0; 4]), vec![1, 1, 1, 0]);
        // ties are by fractional part, not weight scale
        assert_eq!(split_tokens(10, &[2.5; 4]), vec![3, 3, 2, 2]);
    }

    #[test]
    fn split_tokens_conserves_under_adversarial_weights() {
        use crate::util::Rng;
        let adversarial: Vec<Vec<f64>> = vec![
            vec![1e-300, 1.0],            // denormal-scale weight
            vec![1e300, 1.0],             // huge imbalance
            vec![0.0, 1.0, 0.0],          // zeros inside
            vec![f64::MIN_POSITIVE; 5],   // all tiny
            vec![0.1; 10],                // many equal
            vec![0.9999999, 0.0000001],   // near-degenerate
        ];
        for w in &adversarial {
            for t in [0u64, 1, 2, 999, 12_345] {
                let parts = split_tokens(t, w);
                assert_eq!(parts.len(), w.len());
                assert_eq!(parts.iter().sum::<u64>(), t, "weights {w:?} tokens {t}");
            }
        }
        // seeded random weight vectors: conservation and floor/ceil bounds
        let mut rng = Rng::new(0x5EED5);
        for _ in 0..200 {
            let k = rng.gen_range(6) as usize + 1;
            let w: Vec<f64> = (0..k).map(|_| rng.gen_f64()).collect();
            let t = rng.gen_range(10_000);
            let parts = split_tokens(t, &w);
            assert_eq!(parts.iter().sum::<u64>(), t);
            let total: f64 = w.iter().sum();
            if total > 0.0 {
                for (r, &p) in parts.iter().enumerate() {
                    let exact = t as f64 * (w[r] / total);
                    // largest-remainder: every part is its floor or ceiling
                    assert!(
                        (p as f64) >= exact.floor() - 1e-9 && (p as f64) <= exact.ceil() + 1e-9,
                        "part {r}={p} vs exact {exact} (weights {w:?}, tokens {t})"
                    );
                }
            }
        }
    }

    #[test]
    fn project_split_zero_rows_conserve() {
        // senders 1 and 2 originate nothing: splitting must not invent tokens
        let m = TrafficMatrix::from_nested(&[
            vec![0, 30, 0],
            vec![0, 0, 0],
            vec![0, 0, 0],
        ])
        .unwrap();
        let owner = vec![0usize, 1, 2];
        let replicas = vec![vec![0], vec![1, 2], vec![2]];
        let weights = vec![vec![1.0], vec![0.5, 0.5], vec![1.0]];
        let g = m.project_split(&owner, &replicas, &weights, 3);
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
        assert_eq!(g.row_sum(1), 0);
        assert_eq!(g.row_sum(2), 0);
        assert_eq!(g.get(0, 1) + g.get(0, 2), 30);
    }

    #[test]
    fn project_split_singletons_match_project_bitwise() {
        let m = sample();
        let owner = vec![2usize, 0, 1];
        let replicas: Vec<Vec<usize>> = owner.iter().map(|&g| vec![g]).collect();
        let weights: Vec<Vec<f64>> = owner.iter().map(|_| vec![1.0]).collect();
        assert_eq!(
            m.project_split(&owner, &replicas, &weights, 3),
            m.project(&owner, 3)
        );
    }

    #[test]
    fn project_split_spreads_hot_column_and_conserves() {
        // 4 experts on 2 GPUs; expert 0 (on GPU 0) is replicated onto GPU 1
        // with a 50/50 split.
        let m = TrafficMatrix::from_nested(&[
            vec![0, 2, 2, 2],
            vec![40, 0, 1, 1],
            vec![40, 1, 0, 1],
            vec![40, 1, 1, 0],
        ])
        .unwrap();
        let owner = vec![0usize, 0, 1, 1];
        let replicas = vec![vec![0usize, 1], vec![0], vec![1], vec![1]];
        let weights = vec![vec![0.5, 0.5], vec![1.0], vec![1.0], vec![1.0]];
        let g = m.project_split(&owner, &replicas, &weights, 2);
        // token load is conserved
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
        // expert 0's 120 inbound tokens split between the two GPUs, so GPU
        // 0's receive column shrinks vs the unsplit projection
        let unsplit = m.project(&owner, 2);
        assert!(g.col_sum(0) < unsplit.col_sum(0));
        assert!(g.b_max_tokens() < unsplit.b_max_tokens());
    }

    #[test]
    #[should_panic]
    fn project_split_rejects_mismatched_weights() {
        let m = sample();
        m.project_split(
            &[0, 1, 2],
            &[vec![0, 1], vec![1], vec![2]],
            &[vec![1.0], vec![1.0], vec![1.0]],
            3,
        );
    }

    #[test]
    fn merge_groups_localizes_intra_group_traffic() {
        let m = TrafficMatrix::from_nested(&[
            vec![0, 1, 2, 3],
            vec![4, 0, 5, 6],
            vec![7, 8, 0, 9],
            vec![1, 1, 1, 0],
        ])
        .unwrap();
        let g = m.merge_groups(&[vec![0, 1], vec![2, 3]]);
        assert_eq!(g.n(), 2);
        // inter-group 0->1: (0,2)+(0,3)+(1,2)+(1,3) = 2+3+5+6 = 16
        assert_eq!(g.get(0, 1), 16);
        // intra-group traffic moved onto the diagonal: (0,1)+(1,0) = 5
        assert_eq!(g.get(0, 0), 5);
        // expert load is conserved in total
        assert_eq!(
            g.expert_loads().iter().sum::<u64>(),
            m.expert_loads().iter().sum::<u64>()
        );
    }

    // ------------------------------------------------------------------
    // Sparse representation
    // ------------------------------------------------------------------

    #[test]
    fn construction_errors_are_typed() {
        let err = TrafficMatrix::from_rows(3, &[1, 2, 3, 4]).unwrap_err();
        assert_eq!(err, TrafficError::ShapeMismatch { n: 3, len: 4 });
        assert!(err.to_string().contains("9 cells"));
        let err = TrafficMatrix::from_nested(&[vec![0, 1], vec![2]]).unwrap_err();
        assert_eq!(
            err,
            TrafficError::RowLengthMismatch {
                row: 1,
                len: 1,
                n: 2
            }
        );
        assert!(err.to_string().contains("row 1"));
    }

    #[test]
    fn constructors_pick_sparse_by_density() {
        // 64×64 with a single nonzero: sparse
        let mut data = vec![0u64; 64 * 64];
        data[64 * 3 + 5] = 7;
        let m = TrafficMatrix::from_rows(64, &data).unwrap();
        assert!(m.is_sparse());
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(3, 5), 7);
        // fully dense 64×64: dense
        let full = TrafficMatrix::from_rows(64, &[1u64; 64 * 64]).unwrap();
        assert!(!full.is_sparse());
        // small matrices always stay dense, however empty
        let small = TrafficMatrix::from_rows(4, &[0u64; 16]).unwrap();
        assert!(!small.is_sparse());
        // zeros + set stays dense regardless of size
        let z = TrafficMatrix::zeros(128);
        assert!(!z.is_sparse());
        // ... until compacted
        let mut z = z;
        z.set(0, 1, 3);
        let c = z.compact();
        assert!(c.is_sparse());
        assert_eq!(c.get(0, 1), 3);
    }

    fn rand_pair(seed: u64, n: usize, fill_in: u64) -> (TrafficMatrix, TrafficMatrix) {
        use crate::util::Rng;
        let mut rng = Rng::new(seed);
        let mut dense = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if rng.gen_range(4) == 0 {
                    dense.set(i, j, rng.gen_range(fill_in) + 1);
                }
            }
        }
        let sparse = dense.to_sparse();
        assert!(sparse.is_sparse() && !dense.is_sparse());
        (dense, sparse)
    }

    #[test]
    fn sparse_and_dense_agree_cell_by_cell() {
        let (dense, sparse) = rand_pair(0xC0FFEE, 17, 50);
        assert_eq!(dense, sparse);
        assert_eq!(sparse, dense);
        for i in 0..17 {
            for j in 0..17 {
                assert_eq!(dense.get(i, j), sparse.get(i, j));
            }
            assert_eq!(dense.row_sum(i), sparse.row_sum(i));
            assert_eq!(dense.col_sum(i), sparse.col_sum(i));
            assert_eq!(
                dense.row_iter(i).collect::<Vec<_>>(),
                sparse.row_iter(i).collect::<Vec<_>>()
            );
            assert_eq!(
                dense.col_iter(i).collect::<Vec<_>>(),
                sparse.col_iter(i).collect::<Vec<_>>()
            );
        }
        assert_eq!(dense.nnz(), sparse.nnz());
        assert_eq!(dense.total(), sparse.total());
        assert_eq!(dense.b_max_tokens(), sparse.b_max_tokens());
        assert_eq!(dense.expert_loads(), sparse.expert_loads());
        assert_eq!(dense.flows(), sparse.flows());
        assert_eq!(dense.dense_vec(), sparse.dense_vec());
        assert_eq!(sparse.to_dense(), dense);
    }

    #[test]
    fn sparse_mutation_tracks_dense_mirror() {
        use crate::util::Rng;
        let (mut dense, mut sparse) = rand_pair(0xBEEF, 9, 20);
        let mut rng = Rng::new(0xDEAD);
        for _ in 0..500 {
            let i = rng.gen_range(9) as usize;
            let j = rng.gen_range(9) as usize;
            match rng.gen_range(3) {
                0 => {
                    let v = rng.gen_range(10);
                    dense.set(i, j, v);
                    sparse.set(i, j, v);
                }
                1 => {
                    let v = rng.gen_range(10);
                    dense.add(i, j, v);
                    sparse.add(i, j, v);
                }
                _ => {
                    // explicit zeroing exercises sparse entry removal
                    dense.set(i, j, 0);
                    sparse.set(i, j, 0);
                }
            }
        }
        assert_eq!(dense, sparse);
        assert_eq!(dense.nnz(), sparse.nnz());
        assert_eq!(dense.b_max_tokens(), sparse.b_max_tokens());
    }

    #[test]
    fn sparse_operators_match_dense_bit_for_bit() {
        let (dense, sparse) = rand_pair(0xFACE, 13, 40);
        assert_eq!(dense.transpose(), sparse.transpose());
        assert_eq!(dense.sum(&dense), sparse.sum(&sparse));
        assert_eq!(dense.sum(&sparse), sparse.sum(&dense));
        let perm: Vec<usize> = (0..13).map(|i| (i * 5 + 2) % 13).collect();
        assert_eq!(dense.permute(&perm), sparse.permute(&perm));
        let owner: Vec<usize> = (0..13).map(|e| e % 4).collect();
        assert_eq!(dense.project(&owner, 4), sparse.project(&owner, 4));
        let groups: Vec<Vec<usize>> = (0..4)
            .map(|g| (0..13).filter(|e| e % 4 == g).collect())
            .collect();
        assert_eq!(dense.merge_groups(&groups), sparse.merge_groups(&groups));
        let replicas: Vec<Vec<usize>> = (0..13)
            .map(|e| if e == 0 { vec![0, 1, 2] } else { vec![e % 4] })
            .collect();
        let weights: Vec<Vec<f64>> = replicas
            .iter()
            .map(|s| {
                if s.len() == 3 {
                    vec![0.5, 0.3, 0.2]
                } else {
                    vec![1.0]
                }
            })
            .collect();
        assert_eq!(
            dense.project_split(&owner, &replicas, &weights, 4),
            sparse.project_split(&owner, &replicas, &weights, 4)
        );
    }

    #[test]
    fn sparse_transpose_is_o_one_and_correct() {
        let (dense, sparse) = rand_pair(0xABBA, 21, 30);
        let t = sparse.transpose();
        assert!(t.is_sparse());
        for i in 0..21 {
            for j in 0..21 {
                assert_eq!(t.get(j, i), dense.get(i, j));
            }
        }
    }
}
