//! The 𝕏 augmentation of Appendix A.
//!
//! Theorem 4.2's proof converts the traffic matrix `D` into `D' = D + X` with
//! non-negative artificial traffic `X` such that every row and column of `D'`
//! sums to exactly `b_max`. Appendix A proves a non-negative `X` always exists
//! via Farkas' lemma; here we *construct* one with a greedy water-filling pass,
//! which is simultaneously a constructive proof and the first step of the
//! Birkhoff–von-Neumann slot decomposition in [`crate::schedule`].

use super::TrafficMatrix;

/// Augment `d` with artificial traffic so every row and column (diagonal
/// included — artificial self-traffic is free since it never crosses the
/// network) sums to `b_max`. Returns `(d_prime, x)` with `d_prime = d + x`,
/// `x ≥ 0` element-wise.
///
/// Greedy water-filling: walk cells in row-major order; pour
/// `min(row deficit, col deficit)` into each. Because total row deficit equals
/// total column deficit (both are `n·b_max − total`), the greedy pass always
/// terminates with all deficits at zero.
pub fn augment_to_balanced(d: &TrafficMatrix) -> (TrafficMatrix, TrafficMatrix) {
    let n = d.n();
    let b_max = d.b_max_tokens();

    // Deficits measured against off-diagonal sums; artificial traffic may be
    // poured anywhere, including the diagonal (it is never actually sent).
    let mut row_def: Vec<u64> = (0..n).map(|i| b_max - d.row_sum(i)).collect();
    let mut col_def: Vec<u64> = (0..n).map(|j| b_max - d.col_sum(j)).collect();

    let mut x = TrafficMatrix::zeros(n);
    for i in 0..n {
        if row_def[i] == 0 {
            continue;
        }
        for j in 0..n {
            if row_def[i] == 0 {
                break;
            }
            let pour = row_def[i].min(col_def[j]);
            if pour > 0 {
                x.add(i, j, pour);
                row_def[i] -= pour;
                col_def[j] -= pour;
            }
        }
    }
    debug_assert!(row_def.iter().all(|&v| v == 0));
    debug_assert!(col_def.iter().all(|&v| v == 0));

    // `d_prime` carries only wire traffic: real off-diagonal tokens plus the
    // artificial filler. The real diagonal of `d` (tokens local to a GPU) is
    // dropped — it never touches the network and must not consume port budget.
    let mut d_prime = TrafficMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            let real = if i == j { 0 } else { d.get(i, j) };
            d_prime.set(i, j, real + x.get(i, j));
        }
    }
    (d_prime, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Row/col sums of the *augmented* matrix (diagonal included — the
    /// diagonal of `d_prime` is purely artificial) must all equal b_max, and
    /// `d_prime` must equal `d`'s wire traffic plus `x`.
    fn check_balanced(d: &TrafficMatrix) {
        let (dp, x) = augment_to_balanced(d);
        let n = d.n();
        let b = d.b_max_tokens();
        for i in 0..n {
            let row: u64 = (0..n).map(|j| dp.get(i, j)).sum();
            let col: u64 = (0..n).map(|k| dp.get(k, i)).sum();
            assert_eq!(row, b, "row {i}");
            assert_eq!(col, b, "col {i}");
        }
        for i in 0..n {
            for j in 0..n {
                let real = if i == j { 0 } else { d.get(i, j) };
                assert_eq!(dp.get(i, j), real + x.get(i, j));
            }
        }
    }

    #[test]
    fn balances_simple_matrix() {
        check_balanced(&TrafficMatrix::from_nested(&[
            vec![0, 2, 3],
            vec![4, 0, 1],
            vec![0, 6, 0],
        ]));
    }

    #[test]
    fn balances_already_balanced() {
        let d = TrafficMatrix::from_nested(&[vec![0, 2, 2], vec![2, 0, 2], vec![2, 2, 0]]);
        let (_, x) = augment_to_balanced(&d);
        assert_eq!(x.total() + (0..3).map(|i| x.get(i, i)).sum::<u64>(), 0);
        check_balanced(&d);
    }

    #[test]
    fn balances_zero_matrix() {
        check_balanced(&TrafficMatrix::zeros(4));
    }

    #[test]
    fn balances_single_hot_row() {
        check_balanced(&TrafficMatrix::from_nested(&[
            vec![0, 10, 10, 10],
            vec![0, 0, 0, 0],
            vec![1, 0, 0, 0],
            vec![0, 2, 0, 0],
        ]));
    }

    #[test]
    fn balances_seeded_random_matrices() {
        use crate::util::Rng;
        let mut rng = Rng::new(0xA0A0);
        for n in 2..=12 {
            let mut d = TrafficMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        d.set(i, j, rng.gen_range(50));
                    }
                }
            }
            check_balanced(&d);
        }
    }
}
