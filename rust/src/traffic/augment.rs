//! The 𝕏 augmentation of Appendix A, plus the Zipf-skew workload generator.
//!
//! Theorem 4.2's proof converts the traffic matrix `D` into `D' = D + X` with
//! non-negative artificial traffic `X` such that every row and column of `D'`
//! sums to exactly `b_max`. Appendix A proves a non-negative `X` always exists
//! via Farkas' lemma; here we *construct* one with a greedy water-filling pass,
//! which is simultaneously a constructive proof and the first step of the
//! Birkhoff–von-Neumann slot decomposition in [`crate::schedule`].
//!
//! [`zipf_traffic`] generates the *skewed-routing* workloads the replication
//! subsystem ([`crate::replication`]) is built for: every sender originates
//! the same token volume, but destination experts follow a Zipf(α)
//! popularity, so one expert can absorb an arbitrarily large share of the
//! batch as α grows.

use super::TrafficMatrix;
use crate::util::Rng;

/// Normalized Zipf(α) popularity over `n` ranks: rank `r` (0-based) gets
/// weight `(r + 1)^{-α} / H`. `α = 0` is exactly uniform; `α ≈ 1.2` matches
/// heavily skewed production routing.
pub fn zipf_weights(n: usize, alpha: f64) -> Vec<f64> {
    assert!(n > 0, "zipf needs at least one rank");
    assert!(alpha >= 0.0, "zipf exponent must be non-negative");
    let raw: Vec<f64> = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(alpha)).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|w| w / total).collect()
}

/// Deterministic Zipf-skewed all-to-all matrix: `n × n`, expert-indexed.
/// Every sender `i` (the data-parallel shard co-resident with expert `i`)
/// originates exactly `tokens_per_sender` tokens; destinations follow
/// [`zipf_weights`] with the popularity *ranking* permuted by `seed` (so the
/// hot expert's identity varies across seeds while the load shape does not).
/// Rows are integerized by largest-remainder rounding
/// ([`super::split_tokens`]), so the matrix is exactly row-uniform and fully
/// reproducible — no sampling noise. Diagonal entries (tokens routed to the
/// sender's own expert) are kept: they count toward expert compute load but
/// never touch the wire, exactly as in the LIMoE traces.
pub fn zipf_traffic(n: usize, tokens_per_sender: u64, alpha: f64, seed: u64) -> TrafficMatrix {
    drifting_zipf_traffic(n, tokens_per_sender, alpha, seed, 0)
}

/// Per-expert Zipf popularity with the ranking *rotated* by `phase` through
/// the seed's permutation: the expert holding rank `r` at phase 0 holds rank
/// `(r + phase) mod n` afterwards, so the hot expert's identity moves while
/// the load shape stays fixed. Phase 0 is exactly [`zipf_traffic`]'s
/// assignment.
fn rotated_zipf_popularity(n: usize, alpha: f64, seed: u64, phase: usize) -> Vec<f64> {
    let ranks = zipf_weights(n, alpha);
    // Permute which expert holds which popularity rank.
    let perm = Rng::new(seed ^ 0x51F7_2E3A).permutation(n);
    let mut weights = vec![0.0f64; n];
    for (rank, &expert) in perm.iter().enumerate() {
        weights[expert] = ranks[(rank + phase) % n];
    }
    weights
}

/// Drifting variant of [`zipf_traffic`]: the popularity ranking rotates
/// through the seed's permutation as `phase` advances — the *traffic drift*
/// regime the online coordinator ([`crate::coordinator`]) tracks. `phase = 0`
/// is bit-for-bit [`zipf_traffic`]; holding `phase` fixed gives a stationary
/// workload; each phase relocates the hot expert while preserving the exact
/// load shape (the per-expert load multiset is phase-invariant).
pub fn drifting_zipf_traffic(
    n: usize,
    tokens_per_sender: u64,
    alpha: f64,
    seed: u64,
    phase: usize,
) -> TrafficMatrix {
    let weights = rotated_zipf_popularity(n, alpha, seed, phase);
    // Every sender routes identically, so round once and reuse the parts.
    let parts = super::split_tokens(tokens_per_sender, &weights);
    let mut d = TrafficMatrix::zeros(n);
    for i in 0..n {
        for (j, &part) in parts.iter().enumerate() {
            if part > 0 {
                d.add(i, j, part);
            }
        }
    }
    // Large low-α matrices stay dense; heavily-skewed large ones compress.
    d.compact()
}

/// Flash-crowd variant of [`drifting_zipf_traffic`]: the phase's hot expert
/// (popularity rank 0) has its routing share multiplied by `surge` before
/// the row is renormalized, so a viral prompt suddenly concentrates an even
/// larger fraction of every sender's (unchanged) `tokens_per_sender` on one
/// expert — the overload regime that drives the elasticity policy's
/// scale-up trigger. `surge = 1.0` is bit-for-bit [`drifting_zipf_traffic`];
/// row sums are exact for any surge, so the flash crowd shifts load, it does
/// not add tokens.
pub fn flash_crowd_traffic(
    n: usize,
    tokens_per_sender: u64,
    alpha: f64,
    seed: u64,
    phase: usize,
    surge: f64,
) -> TrafficMatrix {
    assert!(surge >= 1.0, "a flash crowd concentrates load, surge >= 1");
    let mut weights = rotated_zipf_popularity(n, alpha, seed, phase);
    let hot = (0..n)
        .max_by(|&a, &b| weights[a].total_cmp(&weights[b]))
        .expect("popularity is non-empty");
    weights[hot] *= surge;
    let total: f64 = weights.iter().sum();
    for w in &mut weights {
        *w /= total;
    }
    let parts = super::split_tokens(tokens_per_sender, &weights);
    let mut d = TrafficMatrix::zeros(n);
    for i in 0..n {
        for (j, &part) in parts.iter().enumerate() {
            if part > 0 {
                d.add(i, j, part);
            }
        }
    }
    d.compact()
}

/// Sampled (noisy) variant of [`drifting_zipf_traffic`]: each sender's
/// `tokens_per_sender` tokens are drawn one by one from the rotated Zipf
/// popularity with an RNG seeded by `draw_seed`, so repeated windows of one
/// stationary phase fluctuate the way live batches do — the regime that
/// separates a smoothing coordinator from naive replan-every-window. Row
/// sums stay exact; only the destination mix is noisy. Deterministic for a
/// fixed `(seed, phase, draw_seed)` triple.
pub fn sampled_zipf_traffic(
    n: usize,
    tokens_per_sender: u64,
    alpha: f64,
    seed: u64,
    phase: usize,
    draw_seed: u64,
) -> TrafficMatrix {
    let weights = rotated_zipf_popularity(n, alpha, seed, phase);
    let mut rng = Rng::new(draw_seed ^ 0xD21F_7A11);
    let mut d = TrafficMatrix::zeros(n);
    for i in 0..n {
        for _ in 0..tokens_per_sender {
            let j = rng.weighted_index(&weights);
            d.add(i, j, 1);
        }
    }
    d.compact()
}

/// Deterministic multiplicative observation jitter in `[1 − amplitude,
/// 1 + amplitude]`, keyed by `(seed, window, lane)` — the same triple always
/// yields the same factor, so noisy-detector runs replay bit-for-bit. The
/// online harness multiplies each degradation-detector ratio by one draw
/// (`lane` distinguishes a GPU's compute channel from its link channel), so
/// the hysteresis bands are exercised under measurement noise without any
/// global RNG state threading through the serving loop.
pub fn multiplicative_noise(seed: u64, window: usize, lane: usize, amplitude: f64) -> f64 {
    assert!((0.0..1.0).contains(&amplitude), "amplitude must sit in [0, 1)");
    if amplitude == 0.0 {
        return 1.0;
    }
    let mut rng = Rng::new(
        seed ^ 0x0B5E_7F01
            ^ (window as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (lane as u64 + 1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    );
    1.0 + amplitude * (2.0 * rng.gen_f64() - 1.0)
}

/// Augment `d` with artificial traffic so every row and column (diagonal
/// included — artificial self-traffic is free since it never crosses the
/// network) sums to `b_max`. Returns `(d_prime, x)` with `d_prime = d + x`,
/// `x ≥ 0` element-wise.
///
/// Greedy water-filling: walk cells in row-major order; pour
/// `min(row deficit, col deficit)` into each. Because total row deficit equals
/// total column deficit (both are `n·b_max − total`), the greedy pass always
/// terminates with all deficits at zero.
pub fn augment_to_balanced(d: &TrafficMatrix) -> (TrafficMatrix, TrafficMatrix) {
    let n = d.n();
    let b_max = d.b_max_tokens();

    // Deficits measured against off-diagonal sums; artificial traffic may be
    // poured anywhere, including the diagonal (it is never actually sent).
    let mut row_def: Vec<u64> = (0..n).map(|i| b_max - d.row_sum(i)).collect();
    let mut col_def: Vec<u64> = (0..n).map(|j| b_max - d.col_sum(j)).collect();

    let mut x = TrafficMatrix::zeros(n);
    for i in 0..n {
        if row_def[i] == 0 {
            continue;
        }
        for j in 0..n {
            if row_def[i] == 0 {
                break;
            }
            let pour = row_def[i].min(col_def[j]);
            if pour > 0 {
                x.add(i, j, pour);
                row_def[i] -= pour;
                col_def[j] -= pour;
            }
        }
    }
    debug_assert!(row_def.iter().all(|&v| v == 0));
    debug_assert!(col_def.iter().all(|&v| v == 0));

    // `d_prime` carries only wire traffic: real off-diagonal tokens plus the
    // artificial filler. The real diagonal of `d` (tokens local to a GPU) is
    // dropped — it never touches the network and must not consume port budget.
    // Nonzero iteration keeps this pass O(nonzeros) on sparse inputs.
    let mut d_prime = TrafficMatrix::zeros(n);
    for i in 0..n {
        for (j, v) in d.row_iter(i) {
            if i != j {
                d_prime.set(i, j, v);
            }
        }
    }
    for i in 0..n {
        for (j, v) in x.row_iter(i) {
            d_prime.add(i, j, v);
        }
    }
    (d_prime, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Row/col sums of the *augmented* matrix (diagonal included — the
    /// diagonal of `d_prime` is purely artificial) must all equal b_max, and
    /// `d_prime` must equal `d`'s wire traffic plus `x`.
    fn check_balanced(d: &TrafficMatrix) {
        let (dp, x) = augment_to_balanced(d);
        let n = d.n();
        let b = d.b_max_tokens();
        for i in 0..n {
            let row: u64 = (0..n).map(|j| dp.get(i, j)).sum();
            let col: u64 = (0..n).map(|k| dp.get(k, i)).sum();
            assert_eq!(row, b, "row {i}");
            assert_eq!(col, b, "col {i}");
        }
        for i in 0..n {
            for j in 0..n {
                let real = if i == j { 0 } else { d.get(i, j) };
                assert_eq!(dp.get(i, j), real + x.get(i, j));
            }
        }
    }

    #[test]
    fn balances_simple_matrix() {
        check_balanced(&TrafficMatrix::from_nested(&[
            vec![0, 2, 3],
            vec![4, 0, 1],
            vec![0, 6, 0],
        ])
        .unwrap());
    }

    #[test]
    fn balances_already_balanced() {
        let d = TrafficMatrix::from_nested(&[vec![0, 2, 2], vec![2, 0, 2], vec![2, 2, 0]]).unwrap();
        let (_, x) = augment_to_balanced(&d);
        assert_eq!(x.total() + (0..3).map(|i| x.get(i, i)).sum::<u64>(), 0);
        check_balanced(&d);
    }

    #[test]
    fn balances_zero_matrix() {
        check_balanced(&TrafficMatrix::zeros(4));
    }

    #[test]
    fn balances_single_hot_row() {
        check_balanced(&TrafficMatrix::from_nested(&[
            vec![0, 10, 10, 10],
            vec![0, 0, 0, 0],
            vec![1, 0, 0, 0],
            vec![0, 2, 0, 0],
        ])
        .unwrap());
    }

    #[test]
    fn zipf_weights_shape() {
        // α = 0 is exactly uniform
        let u = zipf_weights(8, 0.0);
        assert!(u.iter().all(|&w| (w - 0.125).abs() < 1e-12));
        // α > 0 is strictly decreasing in rank and normalized
        let z = zipf_weights(8, 1.2);
        for r in 1..8 {
            assert!(z[r] < z[r - 1]);
        }
        assert!((z.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // heavier α concentrates more mass on the top rank
        assert!(zipf_weights(8, 2.0)[0] > z[0]);
    }

    #[test]
    fn zipf_traffic_rows_are_uniform_and_deterministic() {
        let d = zipf_traffic(8, 100, 1.2, 7);
        for i in 0..8 {
            let row: u64 = (0..8).map(|j| d.get(i, j)).sum();
            assert_eq!(row, 100, "row {i} (diagonal included)");
        }
        // all rows route identically (same weights, same rounding)
        for i in 1..8 {
            for j in 0..8 {
                assert_eq!(d.get(i, j), d.get(0, j));
            }
        }
        assert_eq!(d, zipf_traffic(8, 100, 1.2, 7));
        // a different seed relocates the hot expert but keeps the load shape
        let d2 = zipf_traffic(8, 100, 1.2, 8);
        let mut loads_a = d.expert_loads();
        let mut loads_b = d2.expert_loads();
        loads_a.sort();
        loads_b.sort();
        assert_eq!(loads_a, loads_b);
    }

    #[test]
    fn zipf_alpha_zero_is_uniform_alpha_large_is_hot() {
        let flat = zipf_traffic(16, 160, 0.0, 3);
        let loads = flat.expert_loads();
        assert!(loads.iter().all(|&l| l == 160), "{loads:?}");
        let skewed = zipf_traffic(16, 160, 1.2, 3);
        let max = skewed.expert_loads().into_iter().max().unwrap();
        // Zipf(1.2) over 16 ranks puts ~36% of all tokens on the hot expert
        assert!(max as f64 > 0.3 * 16.0 * 160.0, "hot load {max}");
    }

    #[test]
    fn drifting_phase_zero_is_zipf_traffic() {
        assert_eq!(
            drifting_zipf_traffic(8, 100, 1.2, 7, 0),
            zipf_traffic(8, 100, 1.2, 7)
        );
    }

    #[test]
    fn drifting_phases_relocate_the_hot_expert_but_keep_the_shape() {
        let n = 8;
        let hot_of = |phase: usize| {
            let d = drifting_zipf_traffic(n, 160, 1.2, 7, phase);
            let loads = d.expert_loads();
            (0..n).max_by_key(|&e| loads[e]).unwrap()
        };
        // every phase shifts the hot expert somewhere new; after n phases
        // the rotation wraps around
        let hots: Vec<usize> = (0..n).map(hot_of).collect();
        for p in 1..n {
            assert_ne!(hots[p], hots[0], "phase {p} kept the hot expert");
        }
        assert_eq!(hot_of(n), hots[0]);
        // the load multiset is phase-invariant
        let mut a = drifting_zipf_traffic(n, 160, 1.2, 7, 0).expert_loads();
        let mut b = drifting_zipf_traffic(n, 160, 1.2, 7, 3).expert_loads();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn flash_crowd_concentrates_load_without_adding_tokens() {
        let n = 8;
        let base = drifting_zipf_traffic(n, 400, 1.2, 7, 0);
        // surge 1 is bit-for-bit the plain generator
        assert_eq!(flash_crowd_traffic(n, 400, 1.2, 7, 0, 1.0), base);
        let crowd = flash_crowd_traffic(n, 400, 1.2, 7, 0, 4.0);
        // rows stay exact: the crowd shifts tokens, it does not add them
        for i in 0..n {
            let row: u64 = (0..n).map(|j| crowd.get(i, j)).sum();
            assert_eq!(row, 400, "row {i}");
        }
        assert_eq!(crowd.total(), base.total());
        // the hot expert got hotter, at everyone else's expense
        let hot = |m: &TrafficMatrix| {
            let loads = m.expert_loads();
            (0..n).max_by_key(|&e| loads[e]).unwrap()
        };
        let h = hot(&base);
        assert_eq!(hot(&crowd), h, "the surge hits the phase's hot expert");
        assert!(crowd.expert_loads()[h] > base.expert_loads()[h]);
        // determinism
        assert_eq!(crowd, flash_crowd_traffic(n, 400, 1.2, 7, 0, 4.0));
    }

    #[test]
    fn sampled_windows_conserve_rows_and_track_the_shape() {
        let n = 8;
        let d = sampled_zipf_traffic(n, 400, 1.2, 7, 0, 11);
        for i in 0..n {
            let row: u64 = (0..n).map(|j| d.get(i, j)).sum();
            assert_eq!(row, 400, "row {i} (diagonal included)");
        }
        // deterministic per draw seed, noisy across draw seeds
        assert_eq!(d, sampled_zipf_traffic(n, 400, 1.2, 7, 0, 11));
        assert_ne!(d, sampled_zipf_traffic(n, 400, 1.2, 7, 0, 12));
        // the sample's hot expert matches the exact generator's
        let exact = drifting_zipf_traffic(n, 400, 1.2, 7, 0);
        let hot = |m: &TrafficMatrix| {
            let loads = m.expert_loads();
            (0..n).max_by_key(|&e| loads[e]).unwrap()
        };
        assert_eq!(hot(&d), hot(&exact));
    }

    #[test]
    fn multiplicative_noise_is_bounded_and_deterministic() {
        let a = 0.05;
        for w in 0..40 {
            for lane in 0..8 {
                let f = multiplicative_noise(7, w, lane, a);
                assert!((1.0 - a..=1.0 + a).contains(&f), "factor {f}");
                assert_eq!(f, multiplicative_noise(7, w, lane, a));
            }
        }
        // zero amplitude is exactly the identity
        assert_eq!(multiplicative_noise(7, 3, 1, 0.0), 1.0);
        // different lanes of the same window draw independently
        assert_ne!(
            multiplicative_noise(7, 3, 0, a),
            multiplicative_noise(7, 3, 1, a)
        );
    }

    #[test]
    fn balances_seeded_random_matrices() {
        use crate::util::Rng;
        let mut rng = Rng::new(0xA0A0);
        for n in 2..=12 {
            let mut d = TrafficMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        d.set(i, j, rng.gen_range(50));
                    }
                }
            }
            check_balanced(&d);
        }
    }
}
