//! Incremental (delta) maintenance of the planner's objectives.
//!
//! The local-search refinements of [`crate::planner::Planner::plan_multi`] /
//! [`crate::planner::Planner::plan_topology`] score thousands of candidate
//! moves and swaps. Recomputing the per-GPU completion estimates
//! ([`super::estimate_per_gpu`]) or the cross-uplink drain from scratch for
//! every candidate costs O(models · experts²) each time; at 64–256 GPUs that
//! dominates planning. [`DeltaEstimator`] maintains the same quantities
//! under single-expert moves in **O(expert degree + group degree)** per
//! update.
//!
//! Exactness, not approximation: every maintained quantity is an integer
//! token counter (`u64`), so incremental updates are exact — no
//! floating-point drift ever accumulates. The `f64` estimates are derived
//! from the counters with the *same operation order* as the from-scratch
//! code ([`super::estimate_per_gpu`], [`crate::cluster::uplink_bound`] of
//! the projected aggregate), so a refinement pass driven by the estimator
//! makes bit-for-bit the same accept/reject decisions as one driven by full
//! rescans. The `prop_delta_estimator_matches_full_rescan` property test
//! (in `rust/tests/proptest_invariants.rs`) pins this after randomized
//! move/swap sequences.
//!
//! Counters maintained per committed move of `(model m, expert e)` from GPU
//! `a` to GPU `b`:
//!
//! * per-model per-GPU FFN token load (`e`'s static load relocates);
//! * per-GPU cross-GPU send/receive token totals — only `a`'s and `b`'s
//!   totals change (a flow `e ↔ e2` with `e2` elsewhere merely relabels one
//!   endpoint), updated by walking `e`'s traffic row and column once;
//! * on a two-tier or recursive tiered fabric, per-group uplink up/down
//!   token totals at **every aggregation level** — flows of `e` change
//!   crossing status only relative to their partner's group at each level.
//!
//! Traffic walks iterate the nonzero structure
//! ([`crate::traffic::TrafficMatrix::row_iter`] /
//! [`crate::traffic::TrafficMatrix::col_iter`]), so sparse matrices pay for
//! their flows, not for `n²` — same integer sums either way.
//!
//! Estimates are rebuilt from scratch exactly once per refinement pass (at
//! [`DeltaEstimator::new`]); everything after that is deltas.

use super::Deployment;
use crate::cluster::{Cluster, Topology};
use crate::sim::MoeLayerStats;

/// Incrementally-maintained per-GPU completion estimates and per-uplink
/// token counters for a (mutating) [`Deployment`].
///
/// The estimator keeps its own copy of the expert→GPU assignment;
/// [`DeltaEstimator::apply_move`] advances it. Callers that mutate a
/// `Deployment` alongside (the planner's refinement loops) commit the same
/// move to both. A rejected candidate is undone by applying the inverse
/// move — integer counters make that exact.
#[derive(Debug, Clone)]
pub struct DeltaEstimator<'a> {
    layers: &'a [&'a MoeLayerStats],
    cluster: &'a Cluster,
    /// The estimator's view of `assignments[m][e]` = GPU of model `m`'s
    /// expert `e` (kept in sync by `apply_move`).
    assignments: Vec<Vec<usize>>,
    /// Static per-expert token loads per model.
    loads: Vec<Vec<u64>>,
    /// `gpu_load[m][g]` = model `m`'s token load hosted on GPU `g`.
    gpu_load: Vec<Vec<u64>>,
    /// Cross-GPU tokens sent from / received at each GPU (diagonal excluded,
    /// exactly the projected aggregate's off-diagonal row/col sums).
    out: Vec<u64>,
    inn: Vec<u64>,
    /// Group of each GPU per aggregation level (empty on the big switch;
    /// one level for two-tier; one entry per tier for tiered fabrics).
    owners: Vec<Vec<usize>>,
    /// Per-group uplink rates (tokens/ms) per level.
    rates: Vec<Vec<f64>>,
    /// Cross-group tokens leaving / entering each group, per level.
    up: Vec<Vec<u64>>,
    down: Vec<Vec<u64>>,
    /// Per-GPU completion estimates, always current.
    costs: Vec<f64>,
}

impl<'a> DeltaEstimator<'a> {
    /// Build the counters from scratch for `dep` — the one O(models ·
    /// experts²) pass per refinement; every later update is a delta.
    ///
    /// Panics when `topo` does not fit the cluster (the planner surface
    /// validates topologies before refinement runs).
    pub fn new(
        dep: &Deployment,
        layers: &'a [&'a MoeLayerStats],
        cluster: &'a Cluster,
        topo: &Topology,
    ) -> DeltaEstimator<'a> {
        assert_eq!(layers.len(), dep.n_models(), "one layer per model");
        assert_eq!(cluster.len(), dep.n_gpus, "cluster must match the deployment");
        let n = dep.n_gpus;
        let n_levels = topo.n_levels();
        let owners: Vec<Vec<usize>> = (0..n_levels)
            .map(|l| topo.owners_at(n, l).expect("invalid topology"))
            .collect();
        let rates: Vec<Vec<f64>> = (0..n_levels)
            .map(|l| topo.uplink_rates_at(cluster, l))
            .collect();
        let loads: Vec<Vec<u64>> = layers.iter().map(|l| l.expert_loads()).collect();

        let mut gpu_load = vec![vec![0u64; n]; layers.len()];
        let mut out = vec![0u64; n];
        let mut inn = vec![0u64; n];
        let mut up: Vec<Vec<u64>> = rates.iter().map(|r| vec![0u64; r.len()]).collect();
        let mut down: Vec<Vec<u64>> = rates.iter().map(|r| vec![0u64; r.len()]).collect();
        for (m, layer) in layers.iter().enumerate() {
            let a = &dep.assignments[m];
            for (e, &g) in a.iter().enumerate() {
                gpu_load[m][g] += loads[m][e];
                for (e2, t) in layer.traffic.row_iter(e) {
                    if e == e2 {
                        continue;
                    }
                    let g2 = a[e2];
                    if g != g2 {
                        out[g] += t;
                        inn[g2] += t;
                    }
                    for (l, ow) in owners.iter().enumerate() {
                        if ow[g] != ow[g2] {
                            up[l][ow[g]] += t;
                            down[l][ow[g2]] += t;
                        }
                    }
                }
            }
        }

        let mut est = DeltaEstimator {
            layers,
            cluster,
            assignments: dep.assignments.clone(),
            loads,
            gpu_load,
            out,
            inn,
            owners,
            rates,
            up,
            down,
            costs: vec![0.0; n],
        };
        for g in 0..n {
            est.costs[g] = est.recompute_cost(g);
        }
        est
    }

    /// The per-GPU completion estimate of GPU `g`, derived from the counters
    /// with [`super::estimate_per_gpu`]'s exact operation order.
    fn recompute_cost(&self, g: usize) -> f64 {
        let mut compute = 0.0f64;
        for (m, layer) in self.layers.iter().enumerate() {
            compute +=
                layer.gate_ms + layer.agg_ms + self.gpu_load[m][g] as f64 * layer.ffn_ms_per_token;
        }
        let gpu = self.cluster.gpu(g);
        let wire = self.out[g].max(self.inn[g]) as f64 / gpu.bandwidth;
        compute / gpu.flops_scale + wire
    }

    /// Move model `m`'s expert `e` to GPU `to`, updating every counter in
    /// O(expert degree). A no-op when the expert already lives there.
    pub fn apply_move(&mut self, m: usize, e: usize, to: usize) {
        let from = self.assignments[m][e];
        if from == to {
            return;
        }
        let layer: &MoeLayerStats = self.layers[m];
        let load = self.loads[m][e];
        self.gpu_load[m][from] -= load;
        self.gpu_load[m][to] += load;
        for (e2, t_out) in layer.traffic.row_iter(e) {
            if e2 == e {
                continue;
            }
            let g2 = self.assignments[m][e2];
            if g2 != from {
                self.out[from] -= t_out;
                self.inn[g2] -= t_out;
            }
            if g2 != to {
                self.out[to] += t_out;
                self.inn[g2] += t_out;
            }
            for (l, ow) in self.owners.iter().enumerate() {
                let (hf, ht, h2) = (ow[from], ow[to], ow[g2]);
                if hf != h2 {
                    self.up[l][hf] -= t_out;
                    self.down[l][h2] -= t_out;
                }
                if ht != h2 {
                    self.up[l][ht] += t_out;
                    self.down[l][h2] += t_out;
                }
            }
        }
        for (e2, t_in) in layer.traffic.col_iter(e) {
            if e2 == e {
                continue;
            }
            let g2 = self.assignments[m][e2];
            if g2 != from {
                self.inn[from] -= t_in;
                self.out[g2] -= t_in;
            }
            if g2 != to {
                self.inn[to] += t_in;
                self.out[g2] += t_in;
            }
            for (l, ow) in self.owners.iter().enumerate() {
                let (hf, ht, h2) = (ow[from], ow[to], ow[g2]);
                if h2 != hf {
                    self.up[l][h2] -= t_in;
                    self.down[l][hf] -= t_in;
                }
                if h2 != ht {
                    self.up[l][h2] += t_in;
                    self.down[l][ht] += t_in;
                }
            }
        }
        self.assignments[m][e] = to;
        self.costs[from] = self.recompute_cost(from);
        self.costs[to] = self.recompute_cost(to);
    }

    /// Exchange the GPUs of two experts (two moves; exact under the integer
    /// counters, so applying the same swap again is the exact inverse).
    pub fn apply_swap(&mut self, m1: usize, e1: usize, m2: usize, e2: usize) {
        let g1 = self.assignments[m1][e1];
        let g2 = self.assignments[m2][e2];
        self.apply_move(m1, e1, g2);
        self.apply_move(m2, e2, g1);
    }

    /// GPU currently hosting model `m`'s expert `e` (the estimator's view).
    pub fn gpu_of(&self, m: usize, e: usize) -> usize {
        self.assignments[m][e]
    }

    /// Per-GPU completion estimates — always current; equal to
    /// [`super::estimate_per_gpu`] of the tracked deployment.
    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Completion estimate of one GPU.
    pub fn cost(&self, g: usize) -> f64 {
        self.costs[g]
    }

    /// Max per-GPU completion estimate (the refinement objective's port
    /// half).
    pub fn bottleneck(&self) -> f64 {
        self.costs.iter().cloned().fold(0.0, f64::max)
    }

    /// Cross-uplink drain (ms) of the tracked deployment — equal to
    /// [`crate::cluster::uplink_bound`] of the projected aggregate traffic
    /// (the max across every aggregation level); `0.0` on the big switch.
    pub fn uplink_drain_ms(&self) -> f64 {
        let mut bound = 0.0f64;
        for l in 0..self.owners.len() {
            for ((&u, &d), &r) in self.up[l].iter().zip(&self.down[l]).zip(&self.rates[l]) {
                bound = bound.max(u.max(d) as f64 / r);
            }
        }
        bound
    }

    /// Leaf group of GPU `g` (`None` on the big switch). Two GPUs sharing a
    /// leaf group share every coarser group above it, so a swap between them
    /// changes no level's uplink crossings.
    pub fn group_of_gpu(&self, g: usize) -> Option<usize> {
        self.owners.first().map(|ow| ow[g])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::uplink_bound;
    use crate::placement::{estimate_per_gpu, Scenario};
    use crate::schedule::SchedulePolicy;
    use crate::traffic::TrafficMatrix;
    use crate::util::Rng;

    fn layer(n: usize, seed: u64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(20));
                }
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.1,
            ffn_ms_per_token: 0.01,
            agg_ms: 0.05,
        }
    }

    #[test]
    fn matches_full_estimates_after_random_moves() {
        let la = layer(10, 5);
        let lb = layer(6, 6);
        let layers = [&la, &lb];
        let cluster = Cluster::paper_heterogeneous(4, 80.0);
        let topo = Topology::even_two_tier(4, 2, 4.0).unwrap();
        let mut dep = Deployment::new(
            4,
            vec![vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1], vec![3, 2, 1, 0, 3, 2]],
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        let mut est = DeltaEstimator::new(&dep, &layers, &cluster, &topo);
        let mut rng = Rng::new(99);
        let totals: Vec<MoeLayerStats> = vec![la.clone(), lb.clone()];
        for step in 0..60 {
            let m = rng.gen_range(2) as usize;
            let e = rng.gen_range(dep.assignments[m].len() as u64) as usize;
            let g = rng.gen_range(4) as usize;
            est.apply_move(m, e, g);
            dep.assignments[m][e] = g;
            let refs: Vec<&MoeLayerStats> = totals.iter().collect();
            let full = estimate_per_gpu(&dep, &refs, &cluster);
            for (gpu, &c) in full.iter().enumerate() {
                assert!(
                    (est.cost(gpu) - c).abs() < 1e-12,
                    "step {step} gpu {gpu}: {} vs {c}",
                    est.cost(gpu)
                );
            }
            let agg = dep.aggregated_traffic(&refs);
            let drain = uplink_bound(&agg, &cluster, &topo);
            assert!(
                (est.uplink_drain_ms() - drain).abs() < 1e-12,
                "step {step}: {} vs {drain}",
                est.uplink_drain_ms()
            );
        }
    }

    #[test]
    fn move_then_inverse_restores_counters_exactly() {
        let la = layer(8, 11);
        let layers = [&la];
        let cluster = Cluster::homogeneous(4, 100.0);
        let dep = Deployment::new(
            4,
            vec![vec![0, 1, 2, 3, 0, 1, 2, 3]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let topo = Topology::even_two_tier(4, 2, 2.0).unwrap();
        let before = DeltaEstimator::new(&dep, &layers, &cluster, &topo);
        let mut est = before.clone();
        est.apply_move(0, 3, 0);
        est.apply_move(0, 3, 3);
        assert_eq!(est.out, before.out);
        assert_eq!(est.inn, before.inn);
        assert_eq!(est.up, before.up);
        assert_eq!(est.down, before.down);
        assert_eq!(est.gpu_load, before.gpu_load);
        for g in 0..4 {
            assert_eq!(est.cost(g).to_bits(), before.cost(g).to_bits(), "gpu {g}");
        }
    }

    #[test]
    fn tiered_drain_matches_full_rescan_after_random_moves() {
        // every aggregation level's uplink counters must track the rescanned
        // uplink_bound of the projected aggregate — including sparse inputs
        let la = layer(12, 21);
        let layers = [&la];
        let cluster = Cluster::homogeneous(8, 80.0);
        let topo = Topology::even_tiered(8, &[4, 2], &[2.0, 4.0]).unwrap();
        let mut dep = Deployment::new(
            8,
            vec![vec![0, 1, 2, 3, 4, 5, 6, 7, 0, 2, 4, 6]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let mut est = DeltaEstimator::new(&dep, &layers, &cluster, &topo);
        let sparse_layer = MoeLayerStats {
            traffic: la.traffic.to_sparse(),
            ..la.clone()
        };
        let est_sparse = DeltaEstimator::new(&dep, &[&sparse_layer], &cluster, &topo);
        assert_eq!(est.up, est_sparse.up);
        assert_eq!(est.down, est_sparse.down);
        let mut rng = Rng::new(77);
        for step in 0..40 {
            let e = rng.gen_range(12) as usize;
            let g = rng.gen_range(8) as usize;
            est.apply_move(0, e, g);
            dep.assignments[0][e] = g;
            let refs: Vec<&MoeLayerStats> = vec![&la];
            let agg = dep.aggregated_traffic(&refs);
            let drain = uplink_bound(&agg, &cluster, &topo);
            assert!(
                (est.uplink_drain_ms() - drain).abs() < 1e-12,
                "step {step}: {} vs {drain}",
                est.uplink_drain_ms()
            );
        }
        assert_eq!(est.group_of_gpu(5), Some(2));
    }

    #[test]
    fn big_switch_has_zero_drain_and_no_groups() {
        let la = layer(4, 3);
        let layers = [&la];
        let cluster = Cluster::homogeneous(4, 100.0);
        let dep = Deployment::new(
            4,
            vec![vec![0, 1, 2, 3]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let est = DeltaEstimator::new(&dep, &layers, &cluster, &Topology::BigSwitch);
        assert_eq!(est.uplink_drain_ms(), 0.0);
        assert_eq!(est.group_of_gpu(2), None);
    }
}
