//! The placement core: a first-class [`Deployment`] type mapping
//! `(model, expert)` → GPU.
//!
//! The paper's analysis (§2.4, Fig. 2) fixes two restrictive shapes: at most
//! two models, and exactly one expert (or expert pair) per GPU. This module
//! removes both. A [`Deployment`] may place **M ≥ 1 models** with **any
//! number of experts per GPU**, and a model's expert count need not equal the
//! cluster size. The rest of the stack consumes deployments:
//!
//! * [`crate::planner::Planner::plan_multi`] produces them (exact paper
//!   paths for M ≤ 2 with one expert per GPU; a greedy load-balanced
//!   generalization of Theorem 5.1 plus iterative pairwise bottleneck
//!   matching, generalizing §6/§7.2, elsewhere);
//! * [`crate::sim::simulate_group`] simulates them (compute serializes
//!   across all colocated experts of a GPU; per-GPU traffic aggregates
//!   before [`crate::schedule::comm_time`]);
//! * the two-model [`crate::planner::DeploymentPlan`] is a thin view kept
//!   for figure-reproduction parity.
//!
//! [`Scenario`] — the Fig. 2 decision tree plus the new
//! [`Scenario::MultiColocated`] leaf — also lives here, so an N > 2 request
//! is a planned path rather than a crash.
//!
//! [`DeltaEstimator`] (the [`delta`] submodule) maintains the per-GPU
//! completion estimates and per-uplink token counters *incrementally* under
//! single-expert moves — the engine that lets the planner's local search
//! scale to hundreds of GPUs (see "Performance & incremental planning" in
//! `docs/architecture.md`).

pub mod delta;

pub use delta::DeltaEstimator;

use crate::cluster::{Cluster, Topology};
use crate::schedule::SchedulePolicy;
use crate::sim::{simulate_group, simulate_group_topology, MoeLayerStats, SimResult};
use crate::trace::ModelTrace;
use crate::traffic::TrafficMatrix;
use crate::util::Json;
use std::fmt;

/// Why a deployment (or a plan request) is invalid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlacementError {
    /// A plan was requested for zero models.
    NoModels,
    /// A model has no experts.
    EmptyModel {
        /// Offending model index.
        model: usize,
    },
    /// An expert was placed on a GPU the cluster does not have.
    GpuOutOfRange {
        /// Model index.
        model: usize,
        /// Expert index within the model.
        expert: usize,
        /// The out-of-range GPU id.
        gpu: usize,
        /// Cluster size.
        n_gpus: usize,
    },
    /// A network topology's grouping does not fit the cluster it was planned
    /// against (overlapping, non-covering, or out-of-range groups).
    InvalidTopology {
        /// The underlying [`crate::cluster::TopologyError`], rendered.
        message: String,
    },
}

impl fmt::Display for PlacementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlacementError::NoModels => write!(f, "deployment needs at least one model"),
            PlacementError::EmptyModel { model } => {
                write!(f, "model {model} has no experts")
            }
            PlacementError::GpuOutOfRange {
                model,
                expert,
                gpu,
                n_gpus,
            } => write!(
                f,
                "model {model} expert {expert} placed on GPU {gpu}, but the cluster has {n_gpus}"
            ),
            PlacementError::InvalidTopology { message } => {
                write!(f, "topology does not fit the cluster: {message}")
            }
        }
    }
}

impl std::error::Error for PlacementError {}

/// The Fig. 2 GPU-cluster settings, extended with the generalized leaf.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// One model, identical GPUs (§4). Optimal.
    ExclusiveHomogeneous,
    /// One model, mixed GPUs (§5). Optimal.
    ExclusiveHeterogeneous,
    /// Two models share GPUs, identical GPUs (§6). Optimal.
    ColocatedHomogeneous,
    /// Two models share GPUs, mixed GPUs (§7). NP-hard; 1.07× heuristic.
    ColocatedHeterogeneous,
    /// Three or more models share GPUs (either cluster kind). Beyond the
    /// paper's analysis; planned with the generalized heuristic
    /// ([`crate::planner::Planner::plan_multi`]).
    MultiColocated,
}

impl Scenario {
    /// Scenario for a model count and cluster. `n_models == 0` is the only
    /// invalid request; any positive count is a planned path.
    pub fn detect(n_models: usize, cluster: &Cluster) -> Result<Scenario, PlacementError> {
        Ok(match (n_models, cluster.is_homogeneous()) {
            (0, _) => return Err(PlacementError::NoModels),
            (1, true) => Scenario::ExclusiveHomogeneous,
            (1, false) => Scenario::ExclusiveHeterogeneous,
            (2, true) => Scenario::ColocatedHomogeneous,
            (2, false) => Scenario::ColocatedHeterogeneous,
            (_, _) => Scenario::MultiColocated,
        })
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::ExclusiveHomogeneous => "exclusive+homogeneous",
            Scenario::ExclusiveHeterogeneous => "exclusive+heterogeneous",
            Scenario::ColocatedHomogeneous => "colocating+homogeneous",
            Scenario::ColocatedHeterogeneous => "colocating+heterogeneous",
            Scenario::MultiColocated => "multi-colocated",
        }
    }
}

/// A complete generalized placement: which GPU hosts each expert of each
/// model, plus the communication policy the plan embeds.
///
/// `assignments[m][e]` is the GPU hosting model `m`'s expert `e`. Any number
/// of experts (from one or several models) may share a GPU; a model's expert
/// count is independent of `n_gpus`.
#[derive(Debug, Clone, PartialEq)]
pub struct Deployment {
    /// Cluster size the assignment indexes into.
    pub n_gpus: usize,
    /// `assignments[m][e]` = GPU hosting model `m`'s expert `e`.
    pub assignments: Vec<Vec<usize>>,
    /// Communication scheduling policy.
    pub policy: SchedulePolicy,
    /// Which decision-tree leaf produced this deployment.
    pub scenario: Scenario,
}

impl Deployment {
    /// Build and validate a deployment.
    pub fn new(
        n_gpus: usize,
        assignments: Vec<Vec<usize>>,
        policy: SchedulePolicy,
        scenario: Scenario,
    ) -> Result<Deployment, PlacementError> {
        if assignments.is_empty() {
            return Err(PlacementError::NoModels);
        }
        for (m, a) in assignments.iter().enumerate() {
            if a.is_empty() {
                return Err(PlacementError::EmptyModel { model: m });
            }
            for (e, &g) in a.iter().enumerate() {
                if g >= n_gpus {
                    return Err(PlacementError::GpuOutOfRange {
                        model: m,
                        expert: e,
                        gpu: g,
                        n_gpus,
                    });
                }
            }
        }
        Ok(Deployment {
            n_gpus,
            assignments,
            policy,
            scenario,
        })
    }

    /// Number of colocated models.
    pub fn n_models(&self) -> usize {
        self.assignments.len()
    }

    /// Number of experts of model `m`.
    pub fn n_experts(&self, m: usize) -> usize {
        self.assignments[m].len()
    }

    /// GPU hosting model `m`'s expert `e`.
    pub fn gpu_of(&self, m: usize, e: usize) -> usize {
        self.assignments[m][e]
    }

    /// All `(model, expert)` pairs hosted on GPU `g`, in model-major order.
    pub fn experts_on(&self, g: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for (m, a) in self.assignments.iter().enumerate() {
            for (e, &gpu) in a.iter().enumerate() {
                if gpu == g {
                    out.push((m, e));
                }
            }
        }
        out
    }

    /// Per-GPU expert counts (all models aggregated).
    pub fn experts_per_gpu(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_gpus];
        for a in &self.assignments {
            for &g in a {
                counts[g] += 1;
            }
        }
        counts
    }

    /// Largest number of experts sharing one GPU.
    pub fn max_group_size(&self) -> usize {
        self.experts_per_gpu().into_iter().max().unwrap_or(0)
    }

    /// True when model `m` places exactly one expert on every GPU (its
    /// assignment is a permutation of `0..n_gpus`) — the paper's shape.
    pub fn assignment_is_bijective(&self, m: usize) -> bool {
        let a = &self.assignments[m];
        if a.len() != self.n_gpus {
            return false;
        }
        let mut seen = vec![false; self.n_gpus];
        for &g in a {
            if seen[g] {
                return false;
            }
            seen[g] = true;
        }
        true
    }

    /// True when every model is bijective — the regime where the exact paper
    /// simulators ([`crate::sim::simulate_exclusive`],
    /// [`crate::sim::simulate_colocated`]) apply directly.
    pub fn is_one_expert_per_gpu(&self) -> bool {
        (0..self.n_models()).all(|m| self.assignment_is_bijective(m))
    }

    /// Model `m`'s layer statistics projected onto GPU indices: traffic rows
    /// and columns aggregate by owner GPU; compute scalars carry over.
    pub fn project_layer(&self, m: usize, layer: &MoeLayerStats) -> MoeLayerStats {
        assert_eq!(
            layer.n_experts(),
            self.assignments[m].len(),
            "layer expert count must match model {m}'s assignment"
        );
        MoeLayerStats {
            traffic: layer.traffic.project(&self.assignments[m], self.n_gpus),
            ..*layer
        }
    }

    /// Aggregated GPU-level traffic of all models for one layer set — the
    /// matrix whose [`TrafficMatrix::b_max_tokens`] lower-bounds the shared
    /// all-to-all phase (Theorem 6.1 generalized).
    pub fn aggregated_traffic(&self, layers: &[&MoeLayerStats]) -> TrafficMatrix {
        assert_eq!(layers.len(), self.n_models());
        let mut agg = TrafficMatrix::zeros(self.n_gpus);
        for (m, layer) in layers.iter().enumerate() {
            agg = agg.sum(&layer.traffic.project(&self.assignments[m], self.n_gpus));
        }
        agg
    }

    /// Aggregate a per-expert histogram of model `m` (token counts, as the
    /// serving engine records them) into per-GPU loads under this placement.
    /// This is what the adaptive replanner watches: GPU-group load balance
    /// is the quantity a placement was optimized for.
    pub fn gpu_loads(&self, m: usize, expert_histogram: &[u64]) -> Vec<u64> {
        assert_eq!(
            expert_histogram.len(),
            self.assignments[m].len(),
            "histogram must cover model {m}'s experts"
        );
        let mut loads = vec![0u64; self.n_gpus];
        for (e, &count) in expert_histogram.iter().enumerate() {
            loads[self.assignments[m][e]] += count;
        }
        loads
    }

    /// Simulate one layer (one [`MoeLayerStats`] per model, expert-indexed):
    /// project every model onto GPUs and run the generalized group simulator
    /// under this deployment's policy.
    pub fn simulate_layer(&self, layers: &[&MoeLayerStats], cluster: &Cluster) -> SimResult {
        assert_eq!(layers.len(), self.n_models());
        assert_eq!(cluster.len(), self.n_gpus);
        let projected: Vec<MoeLayerStats> = layers
            .iter()
            .enumerate()
            .map(|(m, l)| self.project_layer(m, l))
            .collect();
        let refs: Vec<&MoeLayerStats> = projected.iter().collect();
        simulate_group(&refs, cluster, self.policy).0
    }

    /// Simulate full traces layer by layer (all traces must have the same
    /// layer count). Returns one [`SimResult`] per layer.
    pub fn simulate(&self, traces: &[&ModelTrace], cluster: &Cluster) -> Vec<SimResult> {
        assert_eq!(traces.len(), self.n_models());
        let n_layers = traces[0].layers.len();
        for t in traces {
            assert_eq!(t.layers.len(), n_layers, "traces must have equal layer counts");
        }
        (0..n_layers)
            .map(|k| {
                let layers: Vec<&MoeLayerStats> = traces.iter().map(|t| &t.layers[k]).collect();
                self.simulate_layer(&layers, cluster)
            })
            .collect()
    }

    /// Total simulated inference time across all layers (ms).
    pub fn total_inference_ms(&self, traces: &[&ModelTrace], cluster: &Cluster) -> f64 {
        self.simulate(traces, cluster)
            .iter()
            .map(|r| r.inference_ms)
            .sum()
    }

    /// [`Deployment::simulate_layer`] on a network topology: collectives are
    /// priced by [`crate::schedule::comm_time_on`]. Big switch ⇒ identical
    /// to [`Deployment::simulate_layer`]. Panics when a two-tier grouping
    /// does not fit `cluster` (the planner surface,
    /// [`crate::planner::Planner::plan_topology`], validates that pairing
    /// and returns a typed error instead).
    pub fn simulate_layer_topology(
        &self,
        layers: &[&MoeLayerStats],
        cluster: &Cluster,
        topo: &Topology,
    ) -> SimResult {
        assert_eq!(layers.len(), self.n_models());
        assert_eq!(cluster.len(), self.n_gpus);
        let projected: Vec<MoeLayerStats> = layers
            .iter()
            .enumerate()
            .map(|(m, l)| self.project_layer(m, l))
            .collect();
        let refs: Vec<&MoeLayerStats> = projected.iter().collect();
        simulate_group_topology(&refs, cluster, topo, self.policy).0
    }

    /// [`Deployment::simulate`] on a network topology, layer by layer.
    pub fn simulate_topology(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
    ) -> Vec<SimResult> {
        assert_eq!(traces.len(), self.n_models());
        let n_layers = traces[0].layers.len();
        for t in traces {
            assert_eq!(t.layers.len(), n_layers, "traces must have equal layer counts");
        }
        (0..n_layers)
            .map(|k| {
                let layers: Vec<&MoeLayerStats> = traces.iter().map(|t| &t.layers[k]).collect();
                self.simulate_layer_topology(&layers, cluster, topo)
            })
            .collect()
    }

    /// Total simulated inference time across all layers on a topology (ms).
    pub fn total_inference_ms_topology(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
    ) -> f64 {
        self.simulate_topology(traces, cluster, topo)
            .iter()
            .map(|r| r.inference_ms)
            .sum()
    }

    /// JSON rendering (CLI output and plan files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::from(self.scenario.name())),
            ("policy", Json::from(self.policy.name())),
            ("n_gpus", Json::from(self.n_gpus)),
            ("n_models", Json::from(self.n_models())),
            (
                "assignments",
                Json::Arr(
                    self.assignments
                        .iter()
                        .map(|a| Json::Arr(a.iter().map(|&g| Json::from(g)).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Per-GPU completion estimates of a deployment on one layer set,
/// generalizing the (pair, GPU) edge weight of §7.2: serialized compute of
/// every colocated expert plus the GPU's worst-direction share of the
/// aggregated wire time.
pub fn estimate_per_gpu(
    deployment: &Deployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
) -> Vec<f64> {
    assert_eq!(layers.len(), deployment.n_models());
    assert_eq!(cluster.len(), deployment.n_gpus);
    let n = deployment.n_gpus;

    // Per-GPU FFN load of each model under the placement, plus the aggregate
    // wire matrix.
    let mut compute = vec![0.0f64; n];
    let mut agg = TrafficMatrix::zeros(n);
    for (m, layer) in layers.iter().enumerate() {
        let proj = layer.traffic.project(&deployment.assignments[m], n);
        let loads = proj.expert_loads();
        for (g, c) in compute.iter_mut().enumerate() {
            // Gate and aggregation run on every GPU (data-parallel shards,
            // observation 2); FFN time scales with the hosted token load.
            *c += layer.gate_ms + layer.agg_ms + loads[g] as f64 * layer.ffn_ms_per_token;
        }
        agg = agg.sum(&proj);
    }

    (0..n)
        .map(|g| {
            let gpu = cluster.gpu(g);
            let wire = agg.row_sum(g).max(agg.col_sum(g)) as f64 / gpu.bandwidth;
            compute[g] / gpu.flops_scale + wire
        })
        .collect()
}

/// Max over [`estimate_per_gpu`] — the objective of the planner's
/// local-search refinement.
pub fn estimate_bottleneck(
    deployment: &Deployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
) -> f64 {
    estimate_per_gpu(deployment, layers, cluster)
        .into_iter()
        .fold(0.0, f64::max)
}

/// [`estimate_per_gpu`] for a **single** GPU, computed directly from the
/// expert-level matrices without projecting anything — O(experts-on-g ×
/// total experts) instead of O(models × experts²). `expert_loads[m]` must
/// be each model's static per-expert loads
/// ([`MoeLayerStats::expert_loads`]). Produces exactly the same value as
/// `estimate_per_gpu(..)[g]` (same floating-point operation order), which
/// is what makes it usable as a one-shot endpoint re-evaluator: a move or
/// swap only changes its endpoint GPUs' costs. (The planner's refinement
/// loops go further and maintain all per-GPU costs incrementally via
/// [`DeltaEstimator`].)
pub fn estimate_one_gpu(
    deployment: &Deployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    expert_loads: &[Vec<u64>],
    g: usize,
) -> f64 {
    assert_eq!(layers.len(), deployment.n_models());
    assert!(g < deployment.n_gpus);
    let mut compute = 0.0f64;
    let mut out = 0u64;
    let mut inn = 0u64;
    for (m, layer) in layers.iter().enumerate() {
        let owners = &deployment.assignments[m];
        let mut load_g = 0u64;
        for (e, &owner) in owners.iter().enumerate() {
            if owner != g {
                continue;
            }
            load_g += expert_loads[m][e];
            for (e2, &owner2) in owners.iter().enumerate() {
                if owner2 != g {
                    out += layer.traffic.get(e, e2);
                    inn += layer.traffic.get(e2, e);
                }
            }
        }
        compute += layer.gate_ms + layer.agg_ms + load_g as f64 * layer.ffn_ms_per_token;
    }
    let gpu = cluster.gpu(g);
    compute / gpu.flops_scale + out.max(inn) as f64 / gpu.bandwidth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn layer(n: usize, seed: u64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(12) + 1);
                }
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.1,
            ffn_ms_per_token: 0.01,
            agg_ms: 0.05,
        }
    }

    #[test]
    fn detect_covers_all_leaves() {
        let homo = Cluster::homogeneous(8, 1.0);
        let het = Cluster::paper_heterogeneous(8, 1.0);
        assert_eq!(Scenario::detect(1, &homo), Ok(Scenario::ExclusiveHomogeneous));
        assert_eq!(
            Scenario::detect(1, &het),
            Ok(Scenario::ExclusiveHeterogeneous)
        );
        assert_eq!(Scenario::detect(2, &homo), Ok(Scenario::ColocatedHomogeneous));
        assert_eq!(
            Scenario::detect(2, &het),
            Ok(Scenario::ColocatedHeterogeneous)
        );
        assert_eq!(Scenario::detect(3, &homo), Ok(Scenario::MultiColocated));
        assert_eq!(Scenario::detect(5, &het), Ok(Scenario::MultiColocated));
        assert_eq!(Scenario::detect(0, &homo), Err(PlacementError::NoModels));
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert_eq!(
            Deployment::new(4, vec![], SchedulePolicy::Aurora, Scenario::MultiColocated),
            Err(PlacementError::NoModels)
        );
        assert_eq!(
            Deployment::new(
                4,
                vec![vec![0, 1], vec![]],
                SchedulePolicy::Aurora,
                Scenario::MultiColocated
            ),
            Err(PlacementError::EmptyModel { model: 1 })
        );
        let err = Deployment::new(
            4,
            vec![vec![0, 4]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::GpuOutOfRange { gpu: 4, .. }));
        assert!(err.to_string().contains("GPU 4"));
    }

    #[test]
    fn groups_and_counts() {
        // 2 models: model 0 has 4 experts on 2 GPUs, model 1 has 2 experts.
        let d = Deployment::new(
            2,
            vec![vec![0, 0, 1, 1], vec![1, 0]],
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        assert_eq!(d.n_models(), 2);
        assert_eq!(d.n_experts(0), 4);
        assert_eq!(d.experts_per_gpu(), vec![3, 3]);
        assert_eq!(d.max_group_size(), 3);
        assert_eq!(d.experts_on(0), vec![(0, 0), (0, 1), (1, 1)]);
        assert!(!d.assignment_is_bijective(0));
        assert!(!d.is_one_expert_per_gpu());
        assert_eq!(d.gpu_of(1, 0), 1);
    }

    #[test]
    fn bijective_detection() {
        let d = Deployment::new(
            3,
            vec![vec![2, 0, 1], vec![0, 1, 2]],
            SchedulePolicy::Aurora,
            Scenario::ColocatedHomogeneous,
        )
        .unwrap();
        assert!(d.assignment_is_bijective(0));
        assert!(d.is_one_expert_per_gpu());
    }

    #[test]
    fn projection_matches_manual_aggregation() {
        let l = layer(4, 7);
        let d = Deployment::new(
            2,
            vec![vec![0, 0, 1, 1]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let p = d.project_layer(0, &l);
        assert_eq!(p.traffic.n(), 2);
        assert_eq!(p.gate_ms, l.gate_ms);
        // total token load conserved
        assert_eq!(
            p.expert_loads().iter().sum::<u64>(),
            l.expert_loads().iter().sum::<u64>()
        );
    }

    #[test]
    fn aggregated_traffic_sums_all_models() {
        let la = layer(3, 1);
        let lb = layer(3, 2);
        let lc = layer(3, 3);
        let d = Deployment::new(
            3,
            vec![vec![0, 1, 2], vec![1, 2, 0], vec![2, 0, 1]],
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        let agg = d.aggregated_traffic(&[&la, &lb, &lc]);
        assert_eq!(agg.total(), la.traffic.total() + lb.traffic.total() + lc.traffic.total());
    }

    #[test]
    fn estimate_prefers_balanced_placements() {
        let la = layer(8, 21);
        let lb = layer(8, 22);
        // paper-scale bandwidth: compute and comm comparable, so spreading
        // wins (at starvation-level bandwidth, localizing everything onto one
        // GPU is genuinely optimal under the model and this would invert)
        let cluster = Cluster::homogeneous(4, 100.0);
        // balanced: two experts per GPU, spread over the four GPUs
        let balanced = Deployment::new(
            4,
            vec![vec![0, 0, 1, 1, 2, 2, 3, 3], vec![0, 1, 2, 3, 0, 1, 2, 3]],
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        // skewed: everything on GPU 0
        let skewed = Deployment::new(
            4,
            vec![vec![0; 8], vec![0; 8]],
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        let eb = estimate_bottleneck(&balanced, &[&la, &lb], &cluster);
        let es = estimate_bottleneck(&skewed, &[&la, &lb], &cluster);
        assert!(eb < es, "balanced {eb} vs skewed {es}");
    }

    #[test]
    fn one_gpu_estimate_matches_full_estimate() {
        let la = layer(8, 31);
        let lb = layer(6, 32);
        let cluster = Cluster::paper_heterogeneous(4, 50.0);
        let d = Deployment::new(
            4,
            vec![vec![0, 1, 2, 3, 0, 1, 2, 3], vec![3, 3, 0, 1, 2, 0]],
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        let layers = [&la, &lb];
        let loads: Vec<Vec<u64>> = layers.iter().map(|l| l.expert_loads()).collect();
        let full = estimate_per_gpu(&d, &layers, &cluster);
        for g in 0..4 {
            let one = estimate_one_gpu(&d, &layers, &cluster, &loads, g);
            assert!(
                (one - full[g]).abs() < 1e-12,
                "gpu {g}: {one} vs {}",
                full[g]
            );
        }
    }

    #[test]
    fn json_shape() {
        let d = Deployment::new(
            2,
            vec![vec![0, 1], vec![1, 0]],
            SchedulePolicy::Aurora,
            Scenario::ColocatedHomogeneous,
        )
        .unwrap();
        let j = d.to_json();
        assert_eq!(j.get("n_models").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("assignments").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("scenario").unwrap().as_str(),
            Some("colocating+homogeneous")
        );
    }
}
