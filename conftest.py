"""Repo-root pytest shim: make `pytest python/tests/` work from the root by
putting the python/ package directory on sys.path."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "python"))
